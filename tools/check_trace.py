#!/usr/bin/env python3
"""Validate a sptlb trace JSONL file (`serve --trace <path>`).

The trace is a Chrome-trace-event array in the truncation-tolerant
streaming form: an opening `[`, then one event object per line with a
trailing comma and no closing bracket. This checker enforces the
structural invariants the tracer guarantees:

  * every event line is well-formed JSON once the trailing comma is
    stripped, with the fields Perfetto needs (ph, pid, ts, name);
  * begin/end spans are balanced per track (tid), LIFO-nested, and an
    `E` always closes the innermost open `B` of the same name;
  * round ids are non-decreasing across the file (the harvest order is
    rounds ascending), and per-track logical timestamps never go
    backwards;
  * decision instants carry the full provenance payload (stage, origin,
    reason, round, app, from, to, detail).

Exit code 0 on a valid trace; 1 with a line-numbered report otherwise.

Usage: python3 tools/check_trace.py <trace.jsonl>
"""

import json
import sys

SPAN_NAMES = {
    "global_round",
    "region_round",
    "collect",
    "forecast",
    "negotiate",
    "solve",
    "vet",
    "adopt",
    "snapshot",
    "ingest_batch",
}

DECISION_ARG_KEYS = {"stage", "origin", "reason", "round", "app", "from", "to", "detail"}


def check(path):
    errors = []
    open_spans = {}  # tid -> [name, ...] stack of open B spans
    last_ts = {}  # tid -> last logical timestamp
    last_round = -1
    n_spans = 0
    n_decisions = 0

    with open(path, encoding="utf-8") as f:
        for lineno, raw in enumerate(f, start=1):
            line = raw.strip()
            if not line or line == "[":
                continue
            if line.endswith(","):
                line = line[:-1]
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"line {lineno}: not valid JSON: {e}")
                continue

            ph = ev.get("ph")
            if ph == "M":  # metadata (process name etc.)
                continue
            if ph not in ("B", "E", "i"):
                errors.append(f"line {lineno}: unexpected phase {ph!r}")
                continue

            tid = ev.get("tid")
            ts = ev.get("ts")
            name = ev.get("name")
            if not isinstance(tid, int) or not isinstance(ts, int):
                errors.append(f"line {lineno}: missing/non-integer tid or ts")
                continue
            if ts < last_ts.get(tid, 0):
                errors.append(
                    f"line {lineno}: ts {ts} went backwards on tid {tid} "
                    f"(last {last_ts[tid]})"
                )
            last_ts[tid] = ts

            if ph == "B":
                n_spans += 1
                if name not in SPAN_NAMES:
                    errors.append(f"line {lineno}: unknown span name {name!r}")
                rnd = ev.get("args", {}).get("round")
                if not isinstance(rnd, int):
                    errors.append(f"line {lineno}: B span without integer args.round")
                else:
                    if rnd < last_round:
                        errors.append(
                            f"line {lineno}: round {rnd} went backwards "
                            f"(last {last_round})"
                        )
                    last_round = max(last_round, rnd)
                open_spans.setdefault(tid, []).append(name)
            elif ph == "E":
                stack = open_spans.get(tid, [])
                if not stack:
                    errors.append(f"line {lineno}: E {name!r} with no open span on tid {tid}")
                elif stack[-1] != name:
                    errors.append(
                        f"line {lineno}: E {name!r} does not close innermost "
                        f"B {stack[-1]!r} on tid {tid}"
                    )
                else:
                    stack.pop()
            else:  # ph == "i": decision instant
                n_decisions += 1
                if name != "decision":
                    errors.append(f"line {lineno}: instant named {name!r}, want 'decision'")
                missing = DECISION_ARG_KEYS - set(ev.get("args", {}))
                if missing:
                    errors.append(
                        f"line {lineno}: decision missing args {sorted(missing)}"
                    )

    for tid, stack in open_spans.items():
        if stack:
            errors.append(f"eof: tid {tid} left unbalanced spans open: {stack}")
    if n_spans == 0:
        errors.append("eof: trace contains no spans")

    return errors, n_spans, n_decisions


def main(argv):
    if len(argv) != 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    try:
        errors, n_spans, n_decisions = check(argv[1])
    except OSError as e:
        print(f"check_trace: cannot read {argv[1]}: {e}", file=sys.stderr)
        return 2
    if errors:
        for e in errors:
            print(f"check_trace: {e}", file=sys.stderr)
        print(f"check_trace: FAIL ({len(errors)} errors)", file=sys.stderr)
        return 1
    print(
        f"check_trace: OK — {n_spans} spans, {n_decisions} decisions, "
        "balanced and monotone"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
