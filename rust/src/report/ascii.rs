//! Terminal bar/scatter rendering for the figure emitters.

/// Horizontal bar chart: one row per (label, value), scaled to `width`
/// columns, with optional reference lines (e.g. 70% ideal, 100% capacity).
pub fn bar_chart(
    title: &str,
    rows: &[(String, f64)],
    max_value: f64,
    width: usize,
    reference_lines: &[(f64, char)],
) -> String {
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    let label_w = rows.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    for (label, value) in rows {
        let frac = (value / max_value).clamp(0.0, 1.0);
        let filled = (frac * width as f64).round() as usize;
        let mut bar: Vec<char> = (0..width)
            .map(|i| if i < filled { '#' } else { ' ' })
            .collect();
        for &(at, ch) in reference_lines {
            let pos = ((at / max_value) * width as f64).round() as usize;
            if pos < width && bar[pos] != '#' {
                bar[pos] = ch;
            } else if pos < width {
                bar[pos] = ch; // reference line wins for visibility
            }
        }
        out.push_str(&format!(
            "  {label:<label_w$} |{}| {value:6.1}%\n",
            bar.iter().collect::<String>()
        ));
    }
    out
}

/// Scatter plot on a character grid; each series gets its own glyph.
pub fn scatter(
    title: &str,
    series: &[(&str, char, Vec<(f64, f64)>)],
    x_label: &str,
    y_label: &str,
    cols: usize,
    rows: usize,
) -> String {
    let all: Vec<(f64, f64)> = series.iter().flat_map(|(_, _, pts)| pts.clone()).collect();
    if all.is_empty() {
        return format!("{title}\n  (no data)\n");
    }
    let (mut x_min, mut x_max) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y_min, mut y_max) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        x_min = x_min.min(x);
        x_max = x_max.max(x);
        y_min = y_min.min(y);
        y_max = y_max.max(y);
    }
    if (x_max - x_min).abs() < 1e-12 {
        x_max = x_min + 1.0;
    }
    if (y_max - y_min).abs() < 1e-12 {
        y_max = y_min + 1.0;
    }
    let mut grid = vec![vec![' '; cols]; rows];
    for (_, glyph, pts) in series {
        for &(x, y) in pts {
            let c = (((x - x_min) / (x_max - x_min)) * (cols - 1) as f64).round() as usize;
            let r = (((y - y_min) / (y_max - y_min)) * (rows - 1) as f64).round() as usize;
            grid[rows - 1 - r][c] = *glyph;
        }
    }
    let mut out = format!("{title}\n");
    out.push_str(&format!("  y: {y_label}  (top={y_max:.2}, bottom={y_min:.2})\n"));
    for row in grid {
        out.push_str("  |");
        out.extend(row);
        out.push('\n');
    }
    out.push_str(&format!(
        "  +{}\n  x: {x_label}  (left={x_min:.2}, right={x_max:.2})\n",
        "-".repeat(cols)
    ));
    let legend: Vec<String> =
        series.iter().map(|(name, glyph, _)| format!("{glyph}={name}")).collect();
    out.push_str(&format!("  legend: {}\n", legend.join("  ")));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bar_chart_scales_and_marks() {
        let rows = vec![("tier1".to_string(), 50.0), ("tier2".to_string(), 100.0)];
        let s = bar_chart("util", &rows, 100.0, 20, &[(70.0, '|')]);
        assert!(s.contains("tier1"));
        assert!(s.contains("50.0%"));
        let t2_line = s.lines().find(|l| l.contains("tier2")).unwrap();
        assert!(t2_line.matches('#').count() >= 19);
    }

    #[test]
    fn scatter_renders_all_series() {
        let s = scatter(
            "fig",
            &[
                ("a", '^', vec![(0.0, 0.0), (1.0, 1.0)]),
                ("b", 'o', vec![(0.5, 0.5)]),
            ],
            "time",
            "value",
            20,
            10,
        );
        assert!(s.contains('^'));
        assert!(s.contains('o'));
        assert!(s.contains("legend"));
    }

    #[test]
    fn scatter_handles_empty() {
        let s = scatter("fig", &[("a", '^', vec![])], "x", "y", 10, 5);
        assert!(s.contains("no data"));
    }

    #[test]
    fn scatter_handles_degenerate_range() {
        let s = scatter("fig", &[("a", '^', vec![(1.0, 1.0), (1.0, 1.0)])], "x", "y", 10, 5);
        assert!(s.contains('^'));
    }
}
