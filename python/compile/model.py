"""L2: the JAX scoring graph SPTLB's rust coordinator executes via PJRT.

Composes the L1 Pallas kernel (``kernels/score.py``) with the batch
reduction the LocalSearch hot loop needs: every candidate's score, the best
candidate's index/score, and the projected tier loads — all from a single
device execution, so rust makes exactly one PJRT dispatch per neighborhood
batch.

The public entry point ``score_and_select`` is what ``aot.py`` lowers to HLO
text.  Shapes are fixed at lowering time (the rust runtime zero-pads apps to
the artifact's ``A`` and candidates to ``B``; zero-resource apps contribute
nothing to any objective, and padded candidates replicate the incumbent so
they never win the argmin by more than a tie).
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import ref as _ref
from .kernels.score import score_candidates_pallas


def score_and_select(assign, res, cap, ideal, init, crit, weights):
    """Score all candidates and select the best.

    Args:
      assign:  (B, A, T) f32 one-hot candidate assignments.
      res:     (A, R) f32 app resources.
      cap:     (T, R) f32 tier capacities.
      ideal:   (T, R) f32 ideal utilization fractions.
      init:    (A, T) f32 one-hot incumbent assignment.
      crit:    (A,) f32 criticality scores.
      weights: (6,) f32 goal weights.

    Returns a 4-tuple (lowered with ``return_tuple=True``):
      scores:     (B,) f32   — per-candidate score, lower is better.
      loads:      (B, T, R) f32 — projected tier loads per candidate.
      best_idx:   () i32     — argmin of scores (first winner on ties).
      best_score: () f32     — scores[best_idx].
    """
    scores, loads = score_candidates_pallas(
        assign, res, cap, ideal, init, crit, weights
    )
    best_idx = jnp.argmin(scores).astype(jnp.int32)
    best_score = scores[best_idx]
    return scores, loads, best_idx, best_score


def score_reference(assign, res, cap, ideal, init, crit, weights):
    """Same graph built on the pure-jnp oracle (used by parity tests)."""
    scores, loads = _ref.score_candidates_ref(
        assign, res, cap, ideal, init, crit, weights
    )
    best_idx = jnp.argmin(scores).astype(jnp.int32)
    best_score = scores[best_idx]
    return scores, loads, best_idx, best_score
