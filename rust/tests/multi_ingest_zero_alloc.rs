//! Multi-region extension of the ingest zero-allocation contract: a
//! warm drift-only *multi-region* round — per-region producer submit,
//! fabric dispatch, per-region drain + admission + journal append +
//! fast-path solve on the pinned workers, `Copy` summary frames back,
//! metric folds — must not touch the global allocator, and must never
//! spawn a thread after warm-up. This covers the full
//! `serve --ingest --regions N` steady-state loop on top of the
//! single-region window in tests/ingest_zero_alloc.rs.
//!
//! Same gated counting allocator; one `#[test]` in this binary so no
//! parallel test bleeds allocations into the counting window. The
//! global policy is `none` so warm rounds stay migration-free (a staged
//! migration is an arrival, which rightly takes the allocating full
//! path).

use sptlb::model::FleetEvent;
use sptlb::service::{MultiRegionService, ServiceConfig};
use sptlb::util::prng::Pcg64;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static COUNTING: AtomicBool = AtomicBool::new(false);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

const REGIONS: usize = 3;
const WARM_ROUNDS: usize = 3;
const MEASURED_ROUNDS: usize = 5;
const BATCH: usize = 16;

#[test]
fn warm_multi_region_ingest_rounds_do_not_allocate() {
    let config = ServiceConfig::builder()
        .workload("small")
        .events("drift")
        .variant("no_cnst")
        .timeout(Duration::from_millis(20))
        .batch_budget(Duration::from_millis(1))
        .max_batch(BATCH)
        .queue_capacity(64)
        .regions(REGIONS)
        .global_policy("none".to_string())
        .build()
        .unwrap();
    let mut service = MultiRegionService::new(config);
    let handle = service.handle();

    // Every per-(round, region) batch is pre-generated outside the
    // counting window; drift events carry only Copy payloads (AppId +
    // fixed ResourceVec array), so moving them through the per-region
    // queues is allocation-free by type.
    let mut rng = Pcg64::new(0x16E57);
    let batches: Vec<Vec<Vec<FleetEvent>>> = (0..1 + WARM_ROUNDS + MEASURED_ROUNDS)
        .map(|_| {
            (0..REGIONS)
                .map(|r| {
                    (0..BATCH)
                        .map(|_| {
                            let apps = service.region_fleet(r).apps();
                            let app = &apps[rng.range(0, apps.len())];
                            FleetEvent::DemandDrift {
                                app: app.id,
                                demand: app.demand * (0.9 + rng.range(0, 21) as f64 / 100.0),
                            }
                        })
                        .collect()
                })
                .collect()
        })
        .collect();

    let mut batches = batches.into_iter();
    // Round 0 primes every region's engine (full path) and spawns the
    // fabric; warm rounds settle the fast path and every pre-reserved
    // buffer.
    for round in batches.by_ref().take(1 + WARM_ROUNDS) {
        for (r, batch) in round.into_iter().enumerate() {
            for ev in batch {
                assert!(handle.submit(r, ev));
            }
        }
        service.ingest_round().expect("queued events produce a round");
    }
    assert_eq!(service.metrics.ingest.fast_rounds as usize, REGIONS * WARM_ROUNDS);
    let warm_spawns = service.fabric_threads_spawned();
    assert_eq!(warm_spawns, REGIONS as u64, "one pinned worker per region");

    ALLOCS.store(0, Ordering::Relaxed);
    COUNTING.store(true, Ordering::Relaxed);
    for round in batches {
        for (r, batch) in round.into_iter().enumerate() {
            for ev in batch {
                handle.submit(r, ev);
            }
        }
        service.ingest_round().expect("queued events produce a round");
    }
    COUNTING.store(false, Ordering::Relaxed);
    let steady = ALLOCS.load(Ordering::Relaxed);

    assert_eq!(
        service.fabric_threads_spawned(),
        warm_spawns,
        "no thread spawns after warm-up"
    );
    assert_eq!(
        service.metrics.ingest.fast_rounds as usize,
        REGIONS * (WARM_ROUNDS + MEASURED_ROUNDS),
        "every warm drift round must take the fast path in every region"
    );
    if cfg!(debug_assertions) {
        // Debug builds allocate inside the engine's loads-equivalence
        // debug_assert (see tests/zero_alloc.rs), once per region per
        // round; allow that and nothing more.
        assert!(
            steady <= (4 * REGIONS * MEASURED_ROUNDS) as u64,
            "debug ingest rounds allocated {steady} times over {MEASURED_ROUNDS} rounds"
        );
    } else {
        assert_eq!(
            steady, 0,
            "warm multi-region rounds must be allocation-free (got {steady} over {MEASURED_ROUNDS} rounds)"
        );
    }
}
