//! Region scheduler (lower-level scheduler #1 in Fig. 2). Its real job at
//! Meta is placing an app's tasks in a region near its data source; in the
//! co-operation protocol it *vets* SPTLB's proposed app→tier mapping: "if
//! it isn't possible to keep an app near its data source with the given
//! tier, it returns false".

use crate::model::{App, Move, Tier};
use crate::network::{app_tier_latency_ms, transition_latencies, LatencyMatrix};
use crate::util::stats::Ecdf;

/// Verdict for one proposed move.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RegionVerdict {
    Accept,
    /// Rejected: best achievable latency to the data source on the
    /// destination tier (ms) exceeded the budget.
    Reject { achievable_ms: f64 },
    /// Rejected: the tier→tier transition's worst-case (p99) latency
    /// exceeded the budget — "high latency transitions" (§4.2.2's
    /// manual_cnst criterion).
    RejectTransition { p99_ms: f64 },
}

impl RegionVerdict {
    /// This layer's verdict in the shared co-operation vocabulary
    /// ([`crate::coop::Verdict`]): proximity misses become point avoids,
    /// high-latency transitions become transition bans.
    pub fn to_coop(self) -> crate::coop::Verdict {
        use crate::coop::{RejectReason, Verdict};
        match self {
            RegionVerdict::Accept => Verdict::Accept,
            RegionVerdict::Reject { achievable_ms } => {
                Verdict::Reject(RejectReason::Proximity { achievable_ms })
            }
            RegionVerdict::RejectTransition { p99_ms } => {
                Verdict::RejectTransition(RejectReason::TransitionLatency { p99_ms })
            }
        }
    }
}

/// Region scheduler over a latency matrix. Rejects a proposed move when
/// EITHER the app cannot stay near its data source on the destination
/// tier (Fig. 2's test) OR the tier→tier transition itself is a
/// high-latency one (the criterion the paper's manual_cnst variant feeds
/// back as avoid constraints).
#[derive(Debug, Clone)]
pub struct RegionScheduler {
    pub latency: LatencyMatrix,
    /// An app is "near its data source" if some region of the hosting
    /// tier is within this budget of its preferred region.
    pub proximity_budget_ms: f64,
    /// Transitions whose worst-case (p99 of the region cross-product)
    /// latency exceeds this are rejected outright.
    pub transition_p99_budget_ms: f64,
}

/// Default worst-case transition budget: adjacent-cluster transitions
/// (~50–110ms in the synthetic matrix) pass; cross-continent (~150ms)
/// fail.
pub const DEFAULT_TRANSITION_P99_MS: f64 = 120.0;

impl RegionScheduler {
    pub fn new(latency: LatencyMatrix, proximity_budget_ms: f64) -> Self {
        Self {
            latency,
            proximity_budget_ms,
            transition_p99_budget_ms: DEFAULT_TRANSITION_P99_MS,
        }
    }

    /// Worst-case (p99) latency of a tier→tier transition.
    pub fn transition_p99_ms(&self, src: &Tier, dst: &Tier) -> f64 {
        Ecdf::new(transition_latencies(src, dst, &self.latency)).p99()
    }

    /// Vet a single proposed move.
    pub fn vet_move(&self, app: &App, src: &Tier, dst: &Tier) -> RegionVerdict {
        let p99 = self.transition_p99_ms(src, dst);
        if p99 > self.transition_p99_budget_ms {
            return RegionVerdict::RejectTransition { p99_ms: p99 };
        }
        let achievable = app_tier_latency_ms(app, dst, &self.latency);
        if achievable <= self.proximity_budget_ms {
            RegionVerdict::Accept
        } else {
            RegionVerdict::Reject { achievable_ms: achievable }
        }
    }

    /// Vet a full move list; returns (move, verdict) pairs.
    pub fn vet(&self, moves: &[Move], apps: &[App], tiers: &[Tier]) -> Vec<(Move, RegionVerdict)> {
        moves
            .iter()
            .map(|m| {
                (
                    *m,
                    self.vet_move(&apps[m.app.idx()], &tiers[m.from.idx()], &tiers[m.to.idx()]),
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{AppId, Criticality, RegionId, RegionSet, ResourceVec, Slo, TierId};
    use crate::model::tier::default_ideal_utilization;
    use crate::util::prng::Pcg64;

    fn app(preferred: usize) -> App {
        App {
            id: AppId(0),
            name: "a".into(),
            demand: ResourceVec::splat(1.0),
            slo: Slo::Slo3,
            criticality: Criticality::new(0.2),
            preferred_region: RegionId(preferred),
        }
    }

    fn tier(regions: &[usize]) -> Tier {
        Tier {
            id: TierId(0),
            name: "t".into(),
            capacity: ResourceVec::splat(100.0),
            ideal_utilization: default_ideal_utilization(),
            supported_slos: vec![Slo::Slo3],
            regions: RegionSet::from_indices(regions.iter().copied()),
        }
    }

    #[test]
    fn accepts_tier_containing_preferred_region() {
        let mut rng = Pcg64::new(1);
        let lat = LatencyMatrix::synthesize(8, 4, &mut rng);
        let sched = RegionScheduler::new(lat, 10.0);
        let src = tier(&[1, 2, 3]);
        assert_eq!(
            sched.vet_move(&app(2), &src, &tier(&[1, 2, 3])),
            RegionVerdict::Accept
        );
    }

    #[test]
    fn rejects_distant_data_source() {
        let mut rng = Pcg64::new(2);
        // Blocked clusters of 2: region 0 in cluster 0; {2,3} cluster 1.
        let lat = LatencyMatrix::synthesize(8, 4, &mut rng);
        let sched = RegionScheduler::new(lat, 10.0);
        let src = tier(&[2, 3]);
        match sched.vet_move(&app(0), &src, &tier(&[2, 3])) {
            RegionVerdict::Reject { achievable_ms } => assert!(achievable_ms > 10.0),
            v => panic!("expected reject, got {v:?}"),
        }
    }

    #[test]
    fn rejects_high_latency_transition() {
        let mut rng = Pcg64::new(4);
        let lat = LatencyMatrix::synthesize(8, 4, &mut rng);
        let sched = RegionScheduler::new(lat, 1e6); // proximity never fails
        let src = tier(&[0, 1]);
        let far = tier(&[6, 7]); // 3 clusters (~150ms) away
        match sched.vet_move(&app(0), &src, &far) {
            RegionVerdict::RejectTransition { p99_ms } => assert!(p99_ms > 120.0),
            v => panic!("expected transition reject, got {v:?}"),
        }
    }

    #[test]
    fn budget_is_inclusive() {
        let mut rng = Pcg64::new(3);
        let lat = LatencyMatrix::synthesize(8, 4, &mut rng);
        let a = app(0);
        let t = tier(&[1]); // same cluster as region 0
        let d = app_tier_latency_ms(&a, &t, &lat);
        let sched = RegionScheduler::new(lat, d);
        let src = tier(&[0]);
        assert_eq!(sched.vet_move(&a, &src, &t), RegionVerdict::Accept);
    }
}
