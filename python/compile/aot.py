"""AOT compile path: lower the L2 scoring graph to HLO *text* artifacts.

Run once at build time (``make artifacts``); the rust coordinator loads the
text with ``HloModuleProto::from_text_file`` and never touches python again.

HLO text — not ``lowered.compile()`` or a serialized ``HloModuleProto`` — is
the interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (what the published ``xla`` 0.1.6 crate links)
rejects (``proto.id() <= INT_MAX``).  The HLO text parser reassigns ids, so
text round-trips cleanly.  See /opt/xla-example/README.md.

Emits one artifact per (A, B) variant plus ``manifest.json`` describing the
shapes so the rust runtime can pick the smallest variant that fits a given
problem and zero-pad to it.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref as _ref

# (name, A apps, T tiers, B candidates).  T=5 matches the paper's testbed;
# A variants cover the workload sizes the benches generate.
DEFAULT_VARIANTS = (
    ("score_a64_t5_b256", 64, 5, 256),
    ("score_a128_t5_b256", 128, 5, 256),
    ("score_a256_t5_b256", 256, 5, 256),
    ("score_a512_t8_b256", 512, 8, 256),
)


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(a: int, t: int, b: int) -> str:
    """Lower ``score_and_select`` for fixed (A, T, B) and return HLO text."""
    f32 = jnp.float32
    specs = (
        jax.ShapeDtypeStruct((b, a, t), f32),  # assign
        jax.ShapeDtypeStruct((a, _ref.NUM_RESOURCES), f32),  # res
        jax.ShapeDtypeStruct((t, _ref.NUM_RESOURCES), f32),  # cap
        jax.ShapeDtypeStruct((t, _ref.NUM_RESOURCES), f32),  # ideal
        jax.ShapeDtypeStruct((a, t), f32),  # init
        jax.ShapeDtypeStruct((a,), f32),  # crit
        jax.ShapeDtypeStruct((_ref.NUM_WEIGHTS,), f32),  # weights
    )
    lowered = jax.jit(model.score_and_select).lower(*specs)
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output dir")
    parser.add_argument(
        "--variants",
        default=None,
        help="comma list name:A:T:B (default: built-in variant set)",
    )
    args = parser.parse_args()

    variants = DEFAULT_VARIANTS
    if args.variants:
        variants = tuple(
            (n, int(a), int(t), int(b))
            for n, a, t, b in (v.split(":") for v in args.variants.split(","))
        )

    os.makedirs(args.out, exist_ok=True)
    manifest = {"format": "hlo-text", "outputs": 4, "variants": []}
    for name, a, t, b in variants:
        text = lower_variant(a, t, b)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["variants"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "apps": a,
                "tiers": t,
                "batch": b,
                "resources": _ref.NUM_RESOURCES,
                "weights": _ref.NUM_WEIGHTS,
            }
        )
        print(f"wrote {path} ({len(text)} chars)  A={a} T={t} B={b}")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
