//! Goal modelling (§3.2.1 statements 5–9). Goals are priority-ordered and
//! always strictly below constraints; the default priority order is the
//! paper's, and alternative orderings are supported as tuning knobs (the
//! paper explored them and found no significant improvement — our ablation
//! bench `fig3_balance --ablate-priorities` reproduces that non-result).

use crate::rebalancer::problem::GoalWeights;

/// The five goals, in the paper's default priority order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Goal {
    /// 5. "Tiers resource utilization is preferred to be under
    ///    utilization limit".
    UtilizationLimit,
    /// 6. "Resource usage is balanced across tiers" (cpu, mem).
    ResourceBalance,
    /// 7. "Task count is balanced across tiers".
    TaskBalance,
    /// 8. "App downtime is low during switch tier" (movement cost is
    ///    task count).
    MoveCost,
    /// 9. "Apps with high criticality scores are not moved frequently".
    CriticalityAffinity,
}

impl Goal {
    pub const DEFAULT_ORDER: [Goal; 5] = [
        Goal::UtilizationLimit,
        Goal::ResourceBalance,
        Goal::TaskBalance,
        Goal::MoveCost,
        Goal::CriticalityAffinity,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Goal::UtilizationLimit => "utilization_limit",
            Goal::ResourceBalance => "resource_balance",
            Goal::TaskBalance => "task_balance",
            Goal::MoveCost => "move_cost",
            Goal::CriticalityAffinity => "criticality_affinity",
        }
    }
}

/// Weight of the capacity (constraint) term — always above every goal.
pub const CAPACITY_WEIGHT: f64 = 1e6;

/// Weight of the forecast-driven predicted-headroom term when the
/// forecasting subsystem is on: a decade above the top goal — the solver
/// must prefer pre-breach moves to any goal trade-off — but well below
/// the capacity constraint, so an *actual* breach always outranks a
/// *predicted* one. The coordinator engine installs it on the round's
/// problem; it is never part of a priority ordering (forecasting is a
/// service-mode feature, not a §3.2.1 goal).
pub const PREDICTED_HEADROOM_WEIGHT: f64 = 1e4;

/// Predicted utilization above this fraction of hard capacity counts as
/// a predicted breach. The 10% margin absorbs forecast error and one
/// round of demand movement, so the proactive path acts *before* the
/// hard-capacity line is in sight.
pub const HEADROOM_LIMIT: f64 = 0.9;

/// Default movement budget as a fraction of the fleet (C3: at most this
/// share of apps may switch tiers per round). Written as a literal rather
/// than `1.0 - HEADROOM_LIMIT` so the derived integer budget
/// (`floor(n_apps * fraction)`) is not perturbed by floating-point
/// rounding; a test pins the two constants as complements. Every test bed
/// and the gap harness plumb this one constant into `Problem::build` so
/// exact and local-search solvers score against the same constraint set.
pub const MOVEMENT_FRACTION: f64 = 0.10;

/// Decade separation between consecutive priorities keeps the ordering
/// effectively lexicographic while remaining a single scalar objective
/// (what Rebalancer's weighted solvers consume).
pub const PRIORITY_DECADE: f64 = 10.0;

/// Derive scalar weights from a priority ordering: the first goal gets
/// 1e3, each subsequent one a decade less.
pub fn weights_from_priorities(order: &[Goal; 5]) -> GoalWeights {
    let mut w = GoalWeights {
        capacity: CAPACITY_WEIGHT,
        util_limit: 0.0,
        res_balance: 0.0,
        task_balance: 0.0,
        move_cost: 0.0,
        criticality: 0.0,
        predicted_headroom: 0.0,
    };
    for (rank, goal) in order.iter().enumerate() {
        let weight = 1e3 / PRIORITY_DECADE.powi(rank as i32);
        match goal {
            Goal::UtilizationLimit => w.util_limit = weight,
            Goal::ResourceBalance => w.res_balance = weight,
            Goal::TaskBalance => w.task_balance = weight,
            Goal::MoveCost => w.move_cost = weight,
            Goal::CriticalityAffinity => w.criticality = weight,
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_order_reproduces_default_weights() {
        let w = weights_from_priorities(&Goal::DEFAULT_ORDER);
        assert_eq!(w, GoalWeights::default());
    }

    #[test]
    fn swapped_priorities_swap_weights() {
        let mut order = Goal::DEFAULT_ORDER;
        order.swap(0, 4); // criticality first, util limit last
        let w = weights_from_priorities(&order);
        assert_eq!(w.criticality, 1e3);
        assert_eq!(w.util_limit, 1e-1);
        assert_eq!(w.res_balance, 1e2); // middle unchanged
    }

    #[test]
    fn capacity_always_dominates() {
        for shift in 0..5 {
            let mut order = Goal::DEFAULT_ORDER;
            order.rotate_left(shift);
            let w = weights_from_priorities(&order);
            for gw in [w.util_limit, w.res_balance, w.task_balance, w.move_cost, w.criticality] {
                assert!(w.capacity > 100.0 * gw);
            }
        }
    }

    #[test]
    fn predicted_headroom_sits_between_goals_and_capacity() {
        // The forecast term must dominate every goal (so predicted
        // breaches are fixed before goal trade-offs) yet stay two decades
        // under the capacity constraint (an actual breach always wins).
        let w = weights_from_priorities(&Goal::DEFAULT_ORDER);
        assert_eq!(w.predicted_headroom, 0.0, "off until the engine enables it");
        assert!(PREDICTED_HEADROOM_WEIGHT > 1e3);
        assert!(CAPACITY_WEIGHT >= 100.0 * PREDICTED_HEADROOM_WEIGHT);
        assert!((0.0..1.0).contains(&HEADROOM_LIMIT));
    }

    #[test]
    fn movement_fraction_complements_headroom_limit() {
        assert!((MOVEMENT_FRACTION - (1.0 - HEADROOM_LIMIT)).abs() < 1e-9);
        assert!((0.0..=1.0).contains(&MOVEMENT_FRACTION));
        // The paper fleet (120 apps) must keep its 12-move budget.
        assert_eq!((120.0 * MOVEMENT_FRACTION).floor() as usize, 12);
    }

    #[test]
    fn names_unique() {
        let names: std::collections::BTreeSet<_> =
            Goal::DEFAULT_ORDER.iter().map(|g| g.name()).collect();
        assert_eq!(names.len(), 5);
    }
}
