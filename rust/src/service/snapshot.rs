//! Durable service state: a JSONL event journal plus periodic snapshot
//! documents, written so that a killed `serve --ingest` process resumes
//! from the latest snapshot and its journal replays bit-identically
//! offline.
//!
//! A snapshot deliberately does *not* serialize the engine's internal
//! state (problem registries, avoid-sets, forecast history): restore
//! rebuilds the fleet from the journaled *initial* checkpoint and
//! replays the journal through the identical pipeline, which re-derives
//! every internal structure by construction. The snapshot's round-K
//! fleet checkpoint is carried purely as an integrity witness — if the
//! catch-up replay does not land exactly on it, the journal or snapshot
//! was tampered with or truncated, and restore fails with
//! [`crate::service::Error::SnapshotCorrupt`] instead of silently
//! diverging.

use crate::model::FleetEvent;
use crate::util::json::Json;
use std::fs;
use std::io::Write as _;
use std::path::Path;

/// Snapshot document schema (bumped together with the metrics schema).
pub const SNAPSHOT_SCHEMA: u32 = 2;

/// A point-in-time capture of a running service.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Rounds journaled (and applied) before this snapshot was taken.
    pub rounds_done: u32,
    /// Fleet checkpoint at round 0, before any journaled event.
    pub initial: Json,
    /// Fleet checkpoint at `rounds_done` — the replay integrity witness.
    pub current: Json,
    /// Workload identity, so a restore against the wrong run is caught
    /// before any replay work happens.
    pub seed: u64,
    pub workload: String,
}

impl Snapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("service_snapshot")),
            ("schema", Json::num(SNAPSHOT_SCHEMA as f64)),
            ("rounds_done", Json::num(self.rounds_done as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("workload", Json::str(&self.workload)),
            ("initial", self.initial.clone()),
            ("current", self.current.clone()),
        ])
    }

    /// Parse a snapshot document; the `Err` carries what was malformed.
    pub fn from_json(j: &Json) -> Result<Snapshot, String> {
        if j.get("kind").as_str() != Some("service_snapshot") {
            return Err("not a service_snapshot document".into());
        }
        let schema = j.get("schema").as_u64().ok_or("missing schema")?;
        if schema != SNAPSHOT_SCHEMA as u64 {
            return Err(format!("unsupported snapshot schema {schema}"));
        }
        let checkpoint = |key: &str| -> Result<Json, String> {
            match j.get(key) {
                Json::Null => Err(format!("missing {key} checkpoint")),
                doc => Ok(doc.clone()),
            }
        };
        Ok(Snapshot {
            rounds_done: j.get("rounds_done").as_u64().ok_or("missing rounds_done")? as u32,
            seed: j.get("seed").as_u64().ok_or("missing seed")?,
            workload: j.get("workload").as_str().ok_or("missing workload")?.to_string(),
            initial: checkpoint("initial")?,
            current: checkpoint("current")?,
        })
    }

    /// Atomically persist: write to `<path>.tmp`, then rename over the
    /// target so a crash mid-write never leaves a torn snapshot.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        let tmp = path.with_extension("tmp");
        fs::write(&tmp, self.to_json().pretty())?;
        fs::rename(&tmp, path)
    }

    pub fn load(path: &Path) -> std::io::Result<Result<Snapshot, String>> {
        let text = fs::read_to_string(path)?;
        Ok(match Json::parse(&text) {
            Ok(j) => Snapshot::from_json(&j),
            Err(e) => Err(format!("unparseable JSON in {}: {e}", path.display())),
        })
    }
}

/// Append one round's admitted events to a JSONL journal: one JSON
/// array per line, fsync-free (the snapshot's integrity witness catches
/// any torn tail on restore).
pub fn append_journal_round(file: &mut fs::File, events: &[FleetEvent]) -> std::io::Result<()> {
    let line = Json::arr(events.iter().map(|e| e.to_json())).to_string();
    writeln!(file, "{line}")
}

/// Load a JSONL journal back into per-round event lists. A truncated or
/// unparseable *final* line (torn by a crash mid-append) is dropped;
/// corruption anywhere earlier is an error.
pub fn load_journal(path: &Path) -> std::io::Result<Result<Vec<Vec<FleetEvent>>, String>> {
    let text = fs::read_to_string(path)?;
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let mut rounds = Vec::with_capacity(lines.len());
    for (i, line) in lines.iter().enumerate() {
        let parsed = Json::parse(line).ok().and_then(|j| {
            j.as_arr()?.iter().map(FleetEvent::from_json).collect::<Option<Vec<_>>>()
        });
        match parsed {
            Some(events) => rounds.push(events),
            None if i + 1 == lines.len() => break, // torn tail from a crash
            None => return Ok(Err(format!("corrupt journal line {}", i + 1))),
        }
    }
    Ok(Ok(rounds))
}

/// Multi-region snapshot document schema (first multi-region version).
pub const MULTI_SNAPSHOT_SCHEMA: u32 = 3;

/// A point-in-time capture of a running multi-region ingest service:
/// the single-region [`Snapshot`] contract extended with a region axis.
/// Checkpoints are per region, ascending region id; `rounds_done`
/// counts *global* committed rounds (every region journals one —
/// possibly empty — event list per committed round, so one journal line
/// covers all regions).
#[derive(Debug, Clone)]
pub struct MultiSnapshot {
    pub rounds_done: u32,
    pub seed: u64,
    pub workload: String,
    /// Region count, so a restore with the wrong `--regions` is caught
    /// before any replay work happens.
    pub regions: u32,
    /// Per-region fleet checkpoints at round 0.
    pub initial: Vec<Json>,
    /// Per-region fleet checkpoints at `rounds_done` — the replay
    /// integrity witnesses.
    pub current: Vec<Json>,
}

impl MultiSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("multi_service_snapshot")),
            ("schema", Json::num(MULTI_SNAPSHOT_SCHEMA as f64)),
            ("rounds_done", Json::num(self.rounds_done as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("workload", Json::str(&self.workload)),
            ("regions", Json::num(self.regions as f64)),
            ("initial", Json::arr(self.initial.iter().cloned())),
            ("current", Json::arr(self.current.iter().cloned())),
        ])
    }

    /// Parse a multi-region snapshot document.
    pub fn from_json(j: &Json) -> Result<MultiSnapshot, String> {
        if j.get("kind").as_str() != Some("multi_service_snapshot") {
            return Err("not a multi_service_snapshot document".into());
        }
        let schema = j.get("schema").as_u64().ok_or("missing schema")?;
        if schema != MULTI_SNAPSHOT_SCHEMA as u64 {
            return Err(format!("unsupported multi snapshot schema {schema}"));
        }
        let regions = j.get("regions").as_u64().ok_or("missing regions")? as u32;
        let checkpoints = |key: &str| -> Result<Vec<Json>, String> {
            let arr = j.get(key).as_arr().ok_or_else(|| format!("missing {key} checkpoints"))?;
            if arr.len() != regions as usize {
                return Err(format!(
                    "{key} holds {} checkpoints for {regions} regions",
                    arr.len()
                ));
            }
            Ok(arr.to_vec())
        };
        Ok(MultiSnapshot {
            rounds_done: j.get("rounds_done").as_u64().ok_or("missing rounds_done")? as u32,
            seed: j.get("seed").as_u64().ok_or("missing seed")?,
            workload: j.get("workload").as_str().ok_or("missing workload")?.to_string(),
            regions,
            initial: checkpoints("initial")?,
            current: checkpoints("current")?,
        })
    }

    /// Atomically persist (same `.tmp` + rename dance as [`Snapshot`]).
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        let tmp = path.with_extension("tmp");
        fs::write(&tmp, self.to_json().pretty())?;
        fs::rename(&tmp, path)
    }

    pub fn load(path: &Path) -> std::io::Result<Result<MultiSnapshot, String>> {
        let text = fs::read_to_string(path)?;
        Ok(match Json::parse(&text) {
            Ok(j) => MultiSnapshot::from_json(&j),
            Err(e) => Err(format!("unparseable JSON in {}: {e}", path.display())),
        })
    }
}

/// Append one committed multi-region round to a JSONL journal: one JSON
/// array-of-arrays per line — `regions[r]` is region `r`'s admitted
/// event list for the round (empty for regions that sat the round out).
pub fn append_multi_journal_round(
    file: &mut fs::File,
    regions: &[&[FleetEvent]],
) -> std::io::Result<()> {
    let rounds = regions.iter().map(|evs| Json::arr(evs.iter().map(|e| e.to_json())));
    let line = Json::arr(rounds).to_string();
    writeln!(file, "{line}")
}

/// Load a multi-region JSONL journal back into per-round, per-region
/// event lists. Same torn-tail contract as [`load_journal`]: a crash
/// mid-append may tear the final line (dropped); corruption anywhere
/// earlier is an error.
pub fn load_multi_journal(
    path: &Path,
) -> std::io::Result<Result<Vec<Vec<Vec<FleetEvent>>>, String>> {
    let text = fs::read_to_string(path)?;
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let mut rounds = Vec::with_capacity(lines.len());
    for (i, line) in lines.iter().enumerate() {
        let parsed = Json::parse(line).ok().and_then(|j| {
            j.as_arr()?
                .iter()
                .map(|region| {
                    region.as_arr()?.iter().map(FleetEvent::from_json).collect::<Option<Vec<_>>>()
                })
                .collect::<Option<Vec<_>>>()
        });
        match parsed {
            Some(regions) => rounds.push(regions),
            None if i + 1 == lines.len() => break, // torn tail from a crash
            None => return Ok(Err(format!("corrupt journal line {}", i + 1))),
        }
    }
    Ok(Ok(rounds))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{AppId, ResourceVec};

    fn events() -> Vec<FleetEvent> {
        vec![
            FleetEvent::DemandDrift {
                app: AppId::from_usize(0),
                demand: ResourceVec::new(1.25, 2.0, 3.0),
            },
            FleetEvent::Departure { app: AppId::from_usize(3) },
        ]
    }

    #[test]
    fn snapshot_document_roundtrips() {
        let snap = Snapshot {
            rounds_done: 5,
            initial: Json::obj(vec![("x", Json::num(1.0))]),
            current: Json::obj(vec![("x", Json::num(2.0))]),
            seed: 42,
            workload: "paper".into(),
        };
        let back = Snapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back.rounds_done, 5);
        assert_eq!(back.seed, 42);
        assert_eq!(back.workload, "paper");
        assert_eq!(back.initial.to_string(), snap.initial.to_string());
        assert_eq!(back.current.to_string(), snap.current.to_string());
    }

    #[test]
    fn malformed_documents_are_rejected_with_a_reason() {
        assert!(Snapshot::from_json(&Json::obj(vec![("kind", Json::str("other"))]))
            .unwrap_err()
            .contains("not a service_snapshot"));
        let wrong_schema = Json::obj(vec![
            ("kind", Json::str("service_snapshot")),
            ("schema", Json::num(1.0)),
        ]);
        assert!(Snapshot::from_json(&wrong_schema).unwrap_err().contains("schema 1"));
    }

    #[test]
    fn journal_roundtrips_and_tolerates_a_torn_tail() {
        let dir = std::env::temp_dir().join(format!("sptlb_journal_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("journal.jsonl");
        {
            let mut f = fs::File::create(&path).unwrap();
            append_journal_round(&mut f, &events()).unwrap();
            append_journal_round(&mut f, &[]).unwrap();
            // Simulate a crash mid-append: a torn, unparseable tail.
            write!(f, "[{{\"kind\":\"demand_dr").unwrap();
        }
        let rounds = load_journal(&path).unwrap().unwrap();
        assert_eq!(rounds.len(), 2, "torn tail dropped");
        assert_eq!(rounds[0], events());
        assert!(rounds[1].is_empty());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corruption_before_the_tail_is_an_error() {
        let dir = std::env::temp_dir().join(format!("sptlb_journal_bad_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("journal.jsonl");
        fs::write(&path, "garbage\n[]\n").unwrap();
        let err = load_journal(&path).unwrap().unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn multi_snapshot_document_roundtrips_and_checks_region_count() {
        let snap = MultiSnapshot {
            rounds_done: 7,
            seed: 42,
            workload: "paper".into(),
            regions: 2,
            initial: vec![Json::num(1.0), Json::num(2.0)],
            current: vec![Json::num(3.0), Json::num(4.0)],
        };
        let back = MultiSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back.rounds_done, 7);
        assert_eq!(back.regions, 2);
        assert_eq!(back.initial.len(), 2);
        assert_eq!(back.current[1].to_string(), snap.current[1].to_string());

        // A single-region snapshot is not silently accepted here.
        let single = Snapshot {
            rounds_done: 1,
            initial: Json::Null,
            current: Json::Null,
            seed: 42,
            workload: "paper".into(),
        };
        assert!(MultiSnapshot::from_json(&single.to_json())
            .unwrap_err()
            .contains("not a multi_service_snapshot"));

        // Checkpoint arrays must cover every region.
        let mut torn = snap.clone();
        torn.current.pop();
        assert!(MultiSnapshot::from_json(&torn.to_json()).unwrap_err().contains("current"));
    }

    #[test]
    fn multi_journal_roundtrips_and_tolerates_a_torn_tail() {
        let dir = std::env::temp_dir().join(format!("sptlb_multi_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("journal.jsonl");
        {
            let mut f = fs::File::create(&path).unwrap();
            append_multi_journal_round(&mut f, &[&events(), &[]]).unwrap();
            append_multi_journal_round(&mut f, &[&[], &events()[..1]]).unwrap();
            // Simulate a crash mid-append: a torn, unparseable tail.
            write!(f, "[[{{\"kind\":\"demand_dr").unwrap();
        }
        let rounds = load_multi_journal(&path).unwrap().unwrap();
        assert_eq!(rounds.len(), 2, "torn tail dropped");
        assert_eq!(rounds[0], vec![events(), vec![]]);
        assert_eq!(rounds[1][1], events()[..1]);
        let err = load_multi_journal(&dir.join("missing.jsonl"));
        assert!(err.is_err(), "missing file is an io error");
        fs::remove_dir_all(&dir).unwrap();
    }
}
