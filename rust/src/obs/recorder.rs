//! Preallocated per-track span/decision recorder.
//!
//! A [`SpanRecorder`] is owned by whoever owns the logical track (a
//! region runtime, the coordinator, the service) and installed into the
//! running thread's slot for the duration of a round. All emission
//! paths are bounded-buffer pushes: once the buffers reach their
//! preallocated capacity further events are dropped and counted, never
//! grown, so tracing at any level stays allocation-free in the warm
//! steady state.

use super::{Decision, SampleKind, SpanKind, TraceLevel, N_HISTS, N_SPAN_KINDS};
use crate::util::stats::Log2Histogram;
use std::time::Instant;

/// Maximum span nesting depth tracked for wall-clock durations.
pub const MAX_SPAN_DEPTH: usize = 16;

/// Preallocated span-event capacity per recorder per round.
const SPAN_CAPACITY: usize = 4096;

/// Preallocated decision-event capacity per recorder per round.
const DECISION_CAPACITY: usize = 8192;

/// One span boundary in logical time. `ts = round * 1e6 + seq` is the
/// deterministic Chrome-trace timestamp.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// Logical track (region index or [`super::GLOBAL_TRACK`]).
    pub track: u16,
    /// [`SpanKind`] discriminant.
    pub kind: u8,
    /// 0 = begin, 1 = end.
    pub phase: u8,
    /// Logical round.
    pub round: u32,
    /// Within-round emission sequence.
    pub seq: u32,
}

impl SpanEvent {
    /// Deterministic trace timestamp in "microseconds".
    pub fn ts(&self) -> u64 {
        self.round as u64 * 1_000_000 + self.seq as u64
    }
}

/// One decision-provenance event in logical time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecisionEvent {
    /// Logical track (region index or [`super::GLOBAL_TRACK`]).
    pub track: u16,
    /// [`super::DecisionStage`] discriminant.
    pub stage: u8,
    /// [`super::Origin`] discriminant.
    pub origin: u8,
    /// [`super::Reason`] discriminant.
    pub reason: u8,
    /// Logical round.
    pub round: u32,
    /// Within-round emission sequence.
    pub seq: u32,
    /// Subject app id ([`super::NO_APP`] for region-scoped events).
    pub app: u32,
    /// Source tier/region (-1 when not applicable).
    pub from: i64,
    /// Destination tier/region (-1 when not applicable).
    pub to: i64,
    /// Reason-specific payload.
    pub detail: f64,
}

impl DecisionEvent {
    /// Deterministic trace timestamp in "microseconds".
    pub fn ts(&self) -> u64 {
        self.round as u64 * 1_000_000 + self.seq as u64
    }
}

/// Per-track ring-buffer recorder over the static span vocabulary.
///
/// Emits logical-time [`SpanEvent`]s/[`DecisionEvent`]s into
/// preallocated buffers and keeps per-kind [`Log2Histogram`]s of
/// wall-clock span durations (telemetry only — wall clock never reaches
/// the trace file).
#[derive(Debug)]
pub struct SpanRecorder {
    level: TraceLevel,
    track: u16,
    round: u32,
    seq: u32,
    spans: Vec<SpanEvent>,
    decisions: Vec<DecisionEvent>,
    stack: [(u8, Instant); MAX_SPAN_DEPTH],
    depth: usize,
    dropped: u64,
    hists: [Log2Histogram; N_HISTS],
}

impl SpanRecorder {
    /// A recorder for `track` at `level`, with all buffers preallocated.
    pub fn new(level: TraceLevel, track: u16) -> Self {
        Self {
            level,
            track,
            round: 0,
            seq: 0,
            spans: Vec::with_capacity(SPAN_CAPACITY),
            decisions: Vec::with_capacity(DECISION_CAPACITY),
            stack: [(0, Instant::now()); MAX_SPAN_DEPTH],
            depth: 0,
            dropped: 0,
            hists: super::hist_array(),
        }
    }

    /// The recorder's configured level.
    pub fn level(&self) -> TraceLevel {
        self.level
    }

    /// The recorder's logical track id.
    pub fn track(&self) -> u16 {
        self.track
    }

    /// Set the logical round and reset the within-round sequence.
    pub fn set_round(&mut self, round: u32) {
        self.round = round;
        self.seq = 0;
    }

    fn next_seq(&mut self) -> u32 {
        let s = self.seq;
        self.seq += 1;
        s
    }

    /// Begin a span (no-op below the span's minimum level).
    pub fn begin(&mut self, kind: SpanKind) {
        if self.level < kind.min_level() {
            return;
        }
        let seq = self.next_seq();
        if self.spans.len() < self.spans.capacity() {
            self.spans.push(SpanEvent {
                track: self.track,
                kind: kind as u8,
                phase: 0,
                round: self.round,
                seq,
            });
        } else {
            self.dropped += 1;
        }
        if self.depth < MAX_SPAN_DEPTH {
            self.stack[self.depth] = (kind as u8, Instant::now());
        }
        self.depth += 1;
    }

    /// End a span begun with [`SpanRecorder::begin`].
    pub fn end(&mut self, kind: SpanKind) {
        if self.level < kind.min_level() {
            return;
        }
        let seq = self.next_seq();
        if self.spans.len() < self.spans.capacity() {
            self.spans.push(SpanEvent {
                track: self.track,
                kind: kind as u8,
                phase: 1,
                round: self.round,
                seq,
            });
        } else {
            self.dropped += 1;
        }
        if self.depth > 0 {
            self.depth -= 1;
            if self.depth < MAX_SPAN_DEPTH {
                let (started_kind, started_at) = self.stack[self.depth];
                if started_kind == kind as u8 {
                    let ns = started_at.elapsed().as_nanos().min(u64::MAX as u128) as u64;
                    self.hists[kind as usize].record(ns);
                }
            }
        }
    }

    /// Emit a decision event (no-op below [`TraceLevel::Decisions`]).
    pub fn decision(&mut self, d: Decision) {
        if self.level < TraceLevel::Decisions {
            return;
        }
        let seq = self.next_seq();
        if self.decisions.len() < self.decisions.capacity() {
            self.decisions.push(DecisionEvent {
                track: self.track,
                stage: d.stage as u8,
                origin: d.origin as u8,
                reason: d.reason as u8,
                round: self.round,
                seq,
                app: d.app,
                from: d.from,
                to: d.to,
                detail: d.detail,
            });
        } else {
            self.dropped += 1;
        }
    }

    /// Record a free-form value (migration distance, batch size) into
    /// its dedicated histogram slot. Active at any level.
    pub fn sample(&mut self, kind: SampleKind, value: u64) {
        self.hists[N_SPAN_KINDS + kind as usize].record(value);
    }

    /// Span events recorded since the last [`SpanRecorder::clear`].
    pub fn spans(&self) -> &[SpanEvent] {
        &self.spans
    }

    /// Decision events recorded since the last [`SpanRecorder::clear`].
    pub fn decisions(&self) -> &[DecisionEvent] {
        &self.decisions
    }

    /// Events dropped due to full buffers (cumulative).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Per-kind histograms: span durations (ns) in the first
    /// [`N_SPAN_KINDS`] slots, free-form samples after.
    pub fn hists(&self) -> &[Log2Histogram; N_HISTS] {
        &self.hists
    }

    /// Clear event buffers (keeping capacity) after a harvest.
    pub fn clear(&mut self) {
        self.spans.clear();
        self.decisions.clear();
        self.depth = 0;
    }

    /// Clear the duration histograms (after the hub merged them).
    pub fn clear_hists(&mut self) {
        for h in &mut self.hists {
            h.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Decision, DecisionStage, Origin, Reason};
    use super::*;

    #[test]
    fn spans_respect_levels_and_balance() {
        let mut r = SpanRecorder::new(TraceLevel::Rounds, 3);
        r.set_round(5);
        r.begin(SpanKind::RegionRound); // rounds-level: recorded
        r.begin(SpanKind::Solve); // spans-level: filtered
        r.end(SpanKind::Solve);
        r.end(SpanKind::RegionRound);
        assert_eq!(r.spans().len(), 2);
        assert_eq!(r.spans()[0].kind, SpanKind::RegionRound as u8);
        assert_eq!(r.spans()[0].phase, 0);
        assert_eq!(r.spans()[1].phase, 1);
        assert_eq!(r.spans()[0].ts(), 5_000_000);
        assert!(r.hists()[SpanKind::RegionRound as usize].count() >= 1);
    }

    #[test]
    fn decisions_only_at_decisions_level() {
        let d = Decision {
            stage: DecisionStage::Proposed,
            origin: Origin::Protocol,
            reason: Reason::None,
            app: 42,
            from: 1,
            to: 2,
            detail: 0.0,
        };
        let mut spans_only = SpanRecorder::new(TraceLevel::Spans, 0);
        spans_only.decision(d);
        assert!(spans_only.decisions().is_empty());
        let mut full = SpanRecorder::new(TraceLevel::Decisions, 0);
        full.decision(d);
        assert_eq!(full.decisions().len(), 1);
        assert_eq!(full.decisions()[0].app, 42);
    }

    #[test]
    fn overflow_drops_and_counts_instead_of_growing() {
        let mut r = SpanRecorder::new(TraceLevel::Decisions, 0);
        let cap = r.spans.capacity();
        for _ in 0..cap + 10 {
            r.begin(SpanKind::Solve);
            r.end(SpanKind::Solve);
        }
        assert_eq!(r.spans().len(), cap);
        assert_eq!(r.spans.capacity(), cap, "buffer must not grow");
        assert_eq!(r.dropped(), 2 * (cap as u64 + 10) - cap as u64);
        r.clear();
        assert!(r.spans().is_empty());
        assert_eq!(r.spans.capacity(), cap, "clear keeps capacity");
    }

    #[test]
    fn clear_resets_rounds_independent_state() {
        let mut r = SpanRecorder::new(TraceLevel::Decisions, 0);
        r.set_round(1);
        r.begin(SpanKind::Solve);
        r.end(SpanKind::Solve);
        r.decision(Decision {
            stage: DecisionStage::Adopted,
            origin: Origin::Engine,
            reason: Reason::None,
            app: 1,
            from: 0,
            to: 1,
            detail: 0.0,
        });
        r.clear();
        assert!(r.spans().is_empty() && r.decisions().is_empty());
        // Histograms survive clear (they are merged separately).
        assert_eq!(r.hists()[SpanKind::Solve as usize].count(), 1);
        r.clear_hists();
        assert_eq!(r.hists()[SpanKind::Solve as usize].count(), 0);
    }
}
