//! Figure/table emitters (DESIGN.md S13): every evaluation artifact the
//! paper shows, regenerated as CSV rows + ASCII charts so `cargo bench`
//! output is directly comparable with the paper's figures.

pub mod ascii;
pub mod figures;

pub use figures::{fig3_report, fig4_rows, fig5_rows, pareto_front, sweep, Fig3Report, SweepRow};
