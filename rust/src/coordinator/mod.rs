//! Coordinator (DESIGN.md S12): the long-running leader loop that turns
//! SPTLB from a one-shot solver into a service. Each *round* it re-collects
//! metrics (workloads drift), runs the pipeline, executes the accepted
//! moves (the assignment becomes the next round's incumbent), appends to
//! the decision log, and emits running metrics. Backpressure: if a round
//! overruns the tick budget, subsequent ticks are skipped rather than
//! queued (the paper's schedulers run on fresh data, never on a backlog).

use crate::metadata::MetadataStore;
use crate::model::{App, Assignment, Tier};
use crate::network::LatencyMatrix;
use crate::sptlb::{BalanceReport, Sptlb, SptlbConfig};
use crate::util::json::Json;
use crate::util::prng::Pcg64;
use crate::util::stats::OnlineStats;
use crate::util::timer::Stopwatch;
use std::time::Duration;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub sptlb: SptlbConfig,
    /// Tick budget per round; rounds that overrun skip following ticks.
    pub tick: Duration,
    /// Per-round multiplicative demand-drift sigma (0 disables drift).
    pub drift_sigma: f64,
    /// Probability a new app arrives in a round.
    pub arrival_prob: f64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            sptlb: SptlbConfig::default(),
            tick: Duration::from_millis(250),
            drift_sigma: 0.05,
            arrival_prob: 0.0,
        }
    }
}

/// One round's record in the decision log.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: u32,
    pub moves_executed: usize,
    pub score: f64,
    pub p99_latency_ms: f64,
    pub worst_imbalance: f64,
    pub pipeline_ms: f64,
    pub ticks_skipped: u32,
}

impl RoundRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("round", Json::num(self.round as f64)),
            ("moves_executed", Json::num(self.moves_executed as f64)),
            ("score", Json::num(self.score)),
            ("p99_latency_ms", Json::num(self.p99_latency_ms)),
            ("worst_imbalance", Json::num(self.worst_imbalance)),
            ("pipeline_ms", Json::num(self.pipeline_ms)),
            ("ticks_skipped", Json::num(self.ticks_skipped as f64)),
        ])
    }
}

/// Aggregated service metrics (the §3.3 "emitted as metrics in the
/// resource endpoint of the SPTLB").
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub imbalance: OnlineStats,
    pub latency_p99: OnlineStats,
    pub pipeline_ms: OnlineStats,
    pub moves: OnlineStats,
    pub rounds: u32,
    pub ticks_skipped: u32,
}

impl ServiceMetrics {
    pub fn to_json(&self) -> Json {
        let stat = |s: &OnlineStats| {
            Json::obj(vec![
                ("mean", Json::num(s.mean())),
                ("min", Json::num(s.min())),
                ("max", Json::num(s.max())),
                ("std", Json::num(s.std_dev())),
            ])
        };
        Json::obj(vec![
            ("rounds", Json::num(self.rounds as f64)),
            ("ticks_skipped", Json::num(self.ticks_skipped as f64)),
            ("imbalance", stat(&self.imbalance)),
            ("latency_p99_ms", stat(&self.latency_p99)),
            ("pipeline_ms", stat(&self.pipeline_ms)),
            ("moves_per_round", stat(&self.moves)),
        ])
    }
}

/// Skip-not-queue backpressure accounting: a round that overruns its tick
/// budget causes the next ⌊elapsed / tick⌋ ticks to be *skipped* — never
/// queued — so every round runs on fresh metrics (the paper's schedulers
/// "run on fresh data, never on a backlog"). A round that fits its tick
/// skips nothing.
pub fn ticks_skipped_for(elapsed: Duration, tick: Duration) -> u32 {
    if elapsed > tick {
        (elapsed.as_nanos() / tick.as_nanos().max(1)) as u32
    } else {
        0
    }
}

/// The leader loop.
pub struct Coordinator {
    pub config: CoordinatorConfig,
    apps: Vec<App>,
    tiers: Vec<Tier>,
    latency: LatencyMatrix,
    current: Assignment,
    rng: Pcg64,
    pub log: Vec<RoundRecord>,
    pub metrics: ServiceMetrics,
}

impl Coordinator {
    pub fn new(
        config: CoordinatorConfig,
        apps: Vec<App>,
        tiers: Vec<Tier>,
        latency: LatencyMatrix,
        initial: Assignment,
    ) -> Self {
        let rng = Pcg64::new(config.sptlb.seed ^ 0xC003D);
        Self {
            config,
            apps,
            tiers,
            latency,
            current: initial,
            rng,
            log: Vec::new(),
            metrics: ServiceMetrics::default(),
        }
    }

    pub fn from_testbed(config: CoordinatorConfig, bed: crate::workload::TestBed) -> Self {
        Self::new(config, bed.apps, bed.tiers, bed.latency, bed.initial)
    }

    pub fn current_assignment(&self) -> &Assignment {
        &self.current
    }

    /// Run `n_rounds` balancing rounds. Returns the per-round reports.
    pub fn run(&mut self, n_rounds: u32) -> Vec<BalanceReport> {
        let mut reports = Vec::with_capacity(n_rounds as usize);
        for round in 0..n_rounds {
            let sw = Stopwatch::start();
            self.drift();

            let store = MetadataStore::from_apps(self.apps.clone())
                .expect("drifted population keeps unique ids");
            let mut cfg = self.config.sptlb.clone();
            cfg.seed = self.config.sptlb.seed.wrapping_add(round as u64);
            let sptlb = Sptlb::new(cfg);
            let report = sptlb.balance(&store, &self.tiers, &self.latency, &self.current);

            // ---- decision execution: adopt the projected mapping.
            let moves = report.solution.moves(&report.problem);
            self.current = report.solution.assignment.clone();

            // ---- backpressure accounting.
            let ticks_skipped = ticks_skipped_for(sw.elapsed(), self.config.tick);

            let worst = crate::hierarchy::variants::worst_imbalance(
                &report.projected_utilization,
                crate::hierarchy::variants::BALANCED_TARGET,
            );
            let record = RoundRecord {
                round,
                moves_executed: moves.len(),
                score: report.solution.score,
                p99_latency_ms: report.p99_latency_ms,
                worst_imbalance: worst,
                pipeline_ms: report.pipeline_ms,
                ticks_skipped,
            };
            self.metrics.rounds += 1;
            self.metrics.ticks_skipped += ticks_skipped;
            self.metrics.imbalance.push(worst);
            self.metrics.latency_p99.push(report.p99_latency_ms);
            self.metrics.pipeline_ms.push(report.pipeline_ms);
            self.metrics.moves.push(moves.len() as f64);
            log::info!(
                "round {round}: {} moves, imbalance {:.3}, p99 {:.0}ms, {:.0}ms",
                moves.len(),
                worst,
                report.p99_latency_ms,
                report.pipeline_ms
            );
            self.log.push(record);
            reports.push(report);
        }
        reports
    }

    /// Workload drift between rounds: lognormal demand wobble plus
    /// optional app arrivals (fresh apps land on their SLO's first tier).
    fn drift(&mut self) {
        if self.config.drift_sigma > 0.0 {
            for app in &mut self.apps {
                let m = self.rng.log_normal(0.0, self.config.drift_sigma);
                app.demand = app.demand.scale(m);
                app.demand.0[2] = app.demand.0[2].round().max(1.0);
            }
        }
        if self.config.arrival_prob > 0.0 && self.rng.chance(self.config.arrival_prob) {
            let id = crate::model::AppId(self.apps.len());
            let template = self.apps[self.rng.range(0, self.apps.len())].clone();
            let tier = crate::workload::tiers_for_slo(template.slo, self.tiers.len())[0];
            self.apps.push(App {
                id,
                name: format!("arrival-{}", id.0),
                ..template
            });
            // Grow the assignment: the new app starts on an allowed tier.
            let mut tiers = self.current.as_slice().to_vec();
            tiers.push(tier);
            self.current = Assignment::new(tiers);
        }
    }

    /// Decision log as a JSON array (persisted by the CLI).
    pub fn log_json(&self) -> Json {
        Json::arr(self.log.iter().map(|r| r.to_json()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate, WorkloadSpec};
    use std::time::Duration;

    fn coordinator(rounds_cfg: impl FnOnce(&mut CoordinatorConfig)) -> Coordinator {
        let bed = generate(&WorkloadSpec::small());
        let mut cfg = CoordinatorConfig {
            sptlb: SptlbConfig {
                timeout: Duration::from_millis(25),
                ..SptlbConfig::default()
            },
            ..CoordinatorConfig::default()
        };
        rounds_cfg(&mut cfg);
        Coordinator::from_testbed(cfg, bed)
    }

    #[test]
    fn runs_rounds_and_logs() {
        let mut c = coordinator(|_| {});
        let reports = c.run(3);
        assert_eq!(reports.len(), 3);
        assert_eq!(c.log.len(), 3);
        assert_eq!(c.metrics.rounds, 3);
        assert!(c.metrics.imbalance.mean().is_finite());
    }

    #[test]
    fn assignment_carries_across_rounds() {
        let mut c = coordinator(|cfg| cfg.drift_sigma = 0.0);
        let before = c.current_assignment().clone();
        let reports = c.run(1);
        let after = c.current_assignment().clone();
        assert_eq!(&after, &reports[0].solution.assignment);
        // Round 2's problem must use round 1's output as incumbent.
        let r2 = c.run(1);
        assert_eq!(r2[0].problem.initial, after);
        let _ = before;
    }

    #[test]
    fn drift_changes_demands() {
        let mut c = coordinator(|cfg| cfg.drift_sigma = 0.2);
        let before: f64 = c.apps.iter().map(|a| a.demand.cpu()).sum();
        c.run(1);
        let after: f64 = c.apps.iter().map(|a| a.demand.cpu()).sum();
        assert_ne!(before, after);
    }

    #[test]
    fn arrivals_grow_population() {
        let mut c = coordinator(|cfg| {
            cfg.arrival_prob = 1.0;
            cfg.drift_sigma = 0.0;
        });
        let n0 = c.apps.len();
        c.run(2);
        assert_eq!(c.apps.len(), n0 + 2);
        assert_eq!(c.current_assignment().n_apps(), n0 + 2);
    }

    #[test]
    fn backpressure_counts_skipped_ticks() {
        let mut c = coordinator(|cfg| {
            cfg.tick = Duration::from_nanos(100); // force overrun
        });
        c.run(1);
        assert!(c.log[0].ticks_skipped >= 1);
        assert!(c.metrics.ticks_skipped >= 1);
    }

    #[test]
    fn ticks_skipped_semantics_pinned() {
        // Regression pin for the skip-not-queue semantics: within-budget
        // rounds skip nothing (including the exact-boundary case), and an
        // overrun skips ⌊elapsed / tick⌋ subsequent ticks.
        let ms = Duration::from_millis;
        assert_eq!(ticks_skipped_for(ms(100), ms(250)), 0);
        assert_eq!(ticks_skipped_for(ms(250), ms(250)), 0, "exact fit is on time");
        assert_eq!(ticks_skipped_for(ms(251), ms(250)), 1);
        assert_eq!(ticks_skipped_for(ms(600), ms(250)), 2);
        assert_eq!(ticks_skipped_for(ms(2500), ms(250)), 10);
        assert_eq!(ticks_skipped_for(Duration::ZERO, ms(250)), 0);
    }

    #[test]
    fn generous_tick_budget_skips_nothing() {
        let mut c = coordinator(|cfg| cfg.tick = Duration::from_secs(3600));
        c.run(3);
        assert_eq!(c.metrics.ticks_skipped, 0);
        assert!(c.log.iter().all(|r| r.ticks_skipped == 0));
    }

    #[test]
    fn skipped_tick_aggregate_matches_decision_log() {
        // The service metric must be exactly the sum of the per-round
        // decision-log entries — skipped ticks are accounted, not queued
        // as extra rounds.
        let mut c = coordinator(|cfg| cfg.tick = Duration::from_micros(50));
        let reports = c.run(4);
        assert_eq!(reports.len(), 4, "skipped ticks never add rounds");
        let from_log: u32 = c.log.iter().map(|r| r.ticks_skipped).sum();
        assert_eq!(c.metrics.ticks_skipped, from_log);
    }

    #[test]
    fn log_json_parses() {
        let mut c = coordinator(|_| {});
        c.run(2);
        let j = c.log_json().pretty();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 2);
        let m = c.metrics.to_json().to_string();
        assert!(crate::util::json::Json::parse(&m).is_ok());
    }
}
