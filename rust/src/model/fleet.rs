//! Fleet events: the typed change log that drives service-mode balancing.
//!
//! The paper's schedulers are long-lived services reacting to drifting
//! application load. Instead of regenerating the whole fleet snapshot
//! every round, the coordinator consumes a stream of [`FleetEvent`]s —
//! demand drift, app arrivals/departures, tier capacity changes, region
//! outages — and both the fleet state and the solver's [`Problem`]
//! (`rebalancer::problem`) apply them *in place*. Round cost then scales
//! with how much actually changed, not with fleet size.
//!
//! Events are plain data: applying the same event log to the same initial
//! state is deterministic, which is what the incremental-vs-rebuild
//! equivalence contract (see `coordinator::engine`) and the replay
//! determinism tests stand on.

use crate::model::app::{App, AppId};
use crate::model::region::RegionId;
use crate::model::resources::ResourceVec;
use crate::model::tier::TierId;
use crate::util::json::Json;

/// One observed change to the fleet. Carried values are *absolute* (the
/// new demand, the complete arriving app), never deltas relative to
/// unstated prior state, so a recorded log replays bit-identically.
#[derive(Debug, Clone, PartialEq)]
pub enum FleetEvent {
    /// An app's registered (peak) demand changed to this absolute value.
    DemandDrift { app: AppId, demand: ResourceVec },
    /// A new app joins the fleet. `app.id` must be the fleet's next
    /// monotonic id (see `FleetState::next_app_id`); the app lands on the
    /// first tier supporting its SLO.
    Arrival { app: App },
    /// An app leaves the fleet. Its id is never reused.
    Departure { app: AppId },
    /// A tier's capacity is rescaled (hosts added or drained).
    TierCapacityChange { tier: TierId, factor: f64 },
    /// A region goes dark: every tier loses the region from its region
    /// set along with a proportional share of its capacity. A tier whose
    /// ONLY region is the outaged one is kept whole (with a warning) —
    /// an empty region set would make it unschedulable.
    RegionOutage { region: RegionId },
}

impl FleetEvent {
    pub fn name(&self) -> &'static str {
        match self {
            FleetEvent::DemandDrift { .. } => "demand_drift",
            FleetEvent::Arrival { .. } => "arrival",
            FleetEvent::Departure { .. } => "departure",
            FleetEvent::TierCapacityChange { .. } => "tier_capacity_change",
            FleetEvent::RegionOutage { .. } => "region_outage",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![("event", Json::str(self.name()))];
        match self {
            FleetEvent::DemandDrift { app, demand } => {
                fields.push(("app", Json::num(app.0 as f64)));
                fields.push(("cpu", Json::num(demand.cpu())));
                fields.push(("mem", Json::num(demand.mem())));
                fields.push(("tasks", Json::num(demand.tasks())));
            }
            FleetEvent::Arrival { app } => {
                fields.push(("app", Json::num(app.id.0 as f64)));
                fields.push(("spec", app.to_json()));
            }
            FleetEvent::Departure { app } => {
                fields.push(("app", Json::num(app.0 as f64)));
            }
            FleetEvent::TierCapacityChange { tier, factor } => {
                fields.push(("tier", Json::num(tier.0 as f64)));
                fields.push(("factor", Json::num(*factor)));
            }
            FleetEvent::RegionOutage { region } => {
                fields.push(("region", Json::num(region.0 as f64)));
            }
        }
        Json::obj(fields)
    }

    /// Parse an event back from its [`FleetEvent::to_json`] form. Float
    /// fields survive exactly (`Json` prints shortest-roundtrip f64), so
    /// a journal written by `sptlb serve --event-log` replays the
    /// recorded run bit-for-bit via `Coordinator::run_events`.
    pub fn from_json(j: &Json) -> Option<FleetEvent> {
        match j.get("event").as_str()? {
            "demand_drift" => Some(FleetEvent::DemandDrift {
                app: AppId::from_usize(j.get("app").as_usize()?),
                demand: ResourceVec::new(
                    j.get("cpu").as_f64()?,
                    j.get("mem").as_f64()?,
                    j.get("tasks").as_f64()?,
                ),
            }),
            "arrival" => Some(FleetEvent::Arrival { app: App::from_json(j.get("spec"))? }),
            "departure" => Some(FleetEvent::Departure { app: AppId::from_usize(j.get("app").as_usize()?) }),
            "tier_capacity_change" => Some(FleetEvent::TierCapacityChange {
                tier: TierId::from_usize(j.get("tier").as_usize()?),
                factor: j.get("factor").as_f64()?,
            }),
            "region_outage" => Some(FleetEvent::RegionOutage {
                region: RegionId(j.get("region").as_usize()?),
            }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Criticality, Slo};

    fn sample_app() -> App {
        App {
            id: AppId(7),
            name: "arrival-7".into(),
            demand: ResourceVec::new(1.0, 2.0, 3.0),
            slo: Slo::Slo3,
            criticality: Criticality::new(0.4),
            preferred_region: RegionId(0),
        }
    }

    #[test]
    fn event_json_names_and_parses() {
        let events = [
            FleetEvent::DemandDrift { app: AppId(3), demand: ResourceVec::new(1.0, 2.0, 3.0) },
            FleetEvent::Arrival { app: sample_app() },
            FleetEvent::Departure { app: AppId(3) },
            FleetEvent::TierCapacityChange { tier: TierId(1), factor: 0.5 },
            FleetEvent::RegionOutage { region: RegionId(2) },
        ];
        for ev in &events {
            let j = ev.to_json().to_string();
            let parsed = Json::parse(&j).unwrap();
            assert_eq!(parsed.get("event").as_str(), Some(ev.name()));
        }
    }

    #[test]
    fn event_json_roundtrips_exactly() {
        // The journal contract: text → parse → same event, bit-for-bit
        // (demand floats use shortest-roundtrip printing).
        let events = [
            FleetEvent::DemandDrift {
                app: AppId(3),
                demand: ResourceVec::new(1.0625, 2.333_333_333_333_333, 3.0),
            },
            FleetEvent::Arrival { app: sample_app() },
            FleetEvent::Departure { app: AppId(3) },
            FleetEvent::TierCapacityChange { tier: TierId(1), factor: 0.4875 },
            FleetEvent::RegionOutage { region: RegionId(2) },
        ];
        for ev in &events {
            let text = ev.to_json().to_string();
            let back = FleetEvent::from_json(&Json::parse(&text).unwrap());
            assert_eq!(back.as_ref(), Some(ev), "{text}");
        }
        assert!(FleetEvent::from_json(&Json::parse(r#"{"event":"zzz"}"#).unwrap()).is_none());
    }

    #[test]
    fn events_compare_structurally() {
        let a = FleetEvent::Departure { app: AppId(1) };
        let b = FleetEvent::Departure { app: AppId(1) };
        let c = FleetEvent::Departure { app: AppId(2) };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
