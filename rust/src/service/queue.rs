//! The bounded, mutex-free ingest queue: a Vyukov-style MPMC ring
//! buffer specialized to [`FleetEvent`]. Producer threads `try_push`
//! concurrently; the single service loop `try_pop`s during its drain
//! window. Capacity is fixed at construction (rounded up to a power of
//! two) — a full queue is the backpressure signal, surfaced to the
//! producer as the rejected event so the shed/block policy can decide
//! what to do with it.
//!
//! No external crates: each slot carries an atomic sequence number that
//! encodes whose turn it is (producer when `seq == pos`, consumer when
//! `seq == pos + 1`), so push and pop synchronize through one
//! acquire/release pair per transfer and never lock. Neither operation
//! touches the allocator — the warm ingest round's zero-allocation
//! contract extends through the queue.

use crate::model::FleetEvent;
use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};

struct Slot {
    /// Turn counter: `pos` ⇒ free for the producer claiming `pos`;
    /// `pos + 1` ⇒ holds that producer's value, free for the consumer;
    /// `pos + capacity` ⇒ recycled for the next lap.
    seq: AtomicUsize,
    value: UnsafeCell<MaybeUninit<FleetEvent>>,
}

/// Bounded lock-free multi-producer event queue.
pub struct IngestQueue {
    slots: Box<[Slot]>,
    mask: usize,
    push_pos: AtomicUsize,
    pop_pos: AtomicUsize,
}

// The UnsafeCell contents are handed off with release/acquire ordering
// on the slot sequence; a slot is only ever touched by the thread whose
// claimed position matches the sequence.
unsafe impl Send for IngestQueue {}
unsafe impl Sync for IngestQueue {}

impl IngestQueue {
    /// A queue holding at least `capacity` events (rounded up to the
    /// next power of two, minimum 2).
    pub fn with_capacity(capacity: usize) -> Self {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Box<[Slot]> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                value: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        Self {
            slots,
            mask: cap - 1,
            push_pos: AtomicUsize::new(0),
            pop_pos: AtomicUsize::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Approximate occupancy (exact when no push/pop races the read).
    pub fn len(&self) -> usize {
        let push = self.push_pos.load(Ordering::Relaxed);
        let pop = self.pop_pos.load(Ordering::Relaxed);
        push.saturating_sub(pop)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue without blocking. On a full queue the event is handed
    /// back untouched so the caller's backpressure policy (shed or
    /// block-and-retry) owns it.
    pub fn try_push(&self, event: FleetEvent) -> Result<(), FleetEvent> {
        let mut pos = self.push_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - pos as isize;
            if diff == 0 {
                match self.push_pos.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        unsafe { (*slot.value.get()).write(event) };
                        slot.seq.store(pos + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(current) => pos = current,
                }
            } else if diff < 0 {
                // The slot is still occupied by a value from the
                // previous lap: the ring is full.
                return Err(event);
            } else {
                pos = self.push_pos.load(Ordering::Relaxed);
            }
        }
    }

    /// Dequeue without blocking; `None` when the queue is empty.
    pub fn try_pop(&self) -> Option<FleetEvent> {
        let mut pos = self.pop_pos.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let diff = seq as isize - (pos + 1) as isize;
            if diff == 0 {
                match self.pop_pos.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let event = unsafe { (*slot.value.get()).assume_init_read() };
                        slot.seq.store(pos + self.mask + 1, Ordering::Release);
                        return Some(event);
                    }
                    Err(current) => pos = current,
                }
            } else if diff < 0 {
                return None;
            } else {
                pos = self.pop_pos.load(Ordering::Relaxed);
            }
        }
    }
}

impl Drop for IngestQueue {
    fn drop(&mut self) {
        // Events own heap (arrival names); drain what was never consumed.
        while self.try_pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{AppId, ResourceVec};
    use std::sync::Arc;

    fn drift(id: usize, cpu: f64) -> FleetEvent {
        FleetEvent::DemandDrift {
            app: AppId::from_usize(id),
            demand: ResourceVec::new(cpu, 1.0, 1.0),
        }
    }

    fn drift_id(ev: &FleetEvent) -> usize {
        match ev {
            FleetEvent::DemandDrift { app, .. } => app.idx(),
            other => panic!("unexpected event {other:?}"),
        }
    }

    #[test]
    fn fifo_order_single_thread() {
        let q = IngestQueue::with_capacity(8);
        assert_eq!(q.capacity(), 8);
        for i in 0..5 {
            q.try_push(drift(i, 1.0)).unwrap();
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(drift_id(&q.try_pop().unwrap()), i);
        }
        assert!(q.try_pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn full_queue_returns_the_event_to_the_producer() {
        let q = IngestQueue::with_capacity(2);
        q.try_push(drift(0, 1.0)).unwrap();
        q.try_push(drift(1, 1.0)).unwrap();
        let rejected = q.try_push(drift(2, 7.5)).unwrap_err();
        assert_eq!(drift_id(&rejected), 2);
        // Popping one frees a slot for exactly the rejected event.
        assert_eq!(drift_id(&q.try_pop().unwrap()), 0);
        q.try_push(rejected).unwrap();
        assert_eq!(drift_id(&q.try_pop().unwrap()), 1);
        assert_eq!(drift_id(&q.try_pop().unwrap()), 2);
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(IngestQueue::with_capacity(0).capacity(), 2);
        assert_eq!(IngestQueue::with_capacity(3).capacity(), 4);
        assert_eq!(IngestQueue::with_capacity(1000).capacity(), 1024);
    }

    #[test]
    fn concurrent_producers_lose_no_accepted_event() {
        const PRODUCERS: usize = 4;
        const PER_PRODUCER: usize = 500;
        let q = Arc::new(IngestQueue::with_capacity(64));
        let handles: Vec<_> = (0..PRODUCERS)
            .map(|w| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..PER_PRODUCER {
                        let mut ev = drift(w * PER_PRODUCER + i, 1.0);
                        // Block-style retry: every event must land.
                        loop {
                            match q.try_push(ev) {
                                Ok(()) => break,
                                Err(back) => {
                                    ev = back;
                                    std::thread::yield_now();
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        let mut seen = vec![false; PRODUCERS * PER_PRODUCER];
        let mut popped = 0;
        while popped < PRODUCERS * PER_PRODUCER {
            match q.try_pop() {
                Some(ev) => {
                    let id = drift_id(&ev);
                    assert!(!seen[id], "event {id} delivered twice");
                    seen[id] = true;
                    popped += 1;
                }
                None => std::thread::yield_now(),
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(seen.iter().all(|&s| s), "every accepted event delivered");
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn drop_releases_undelivered_events() {
        let q = IngestQueue::with_capacity(8);
        for i in 0..6 {
            q.try_push(drift(i, 1.0)).unwrap();
        }
        drop(q); // must not leak the six undelivered events
    }
}
