//! Network cost model (Fig. 4 substitution). Production has measured
//! region-to-region latency tables; we synthesize a geo-clustered matrix
//! (symmetric, triangle-inequality-respecting) and implement the paper's
//! evaluation procedure: for each (source tier, destination tier)
//! transition produced by a balancing run, sample the transition's latency
//! distribution proportionally to the apps moved, build a CDF over all
//! samples, and report its p99 — "the worst case scenario network latency"
//! — approximated to the closest millisecond.

use crate::model::{App, Assignment, Move, RegionId, Tier};
use crate::util::prng::Pcg64;
use crate::util::stats::Ecdf;

/// Symmetric region→region latency matrix in milliseconds.
#[derive(Debug, Clone)]
pub struct LatencyMatrix {
    n: usize,
    ms: Vec<f64>, // row-major n×n
}

impl LatencyMatrix {
    /// Build from explicit entries (must be symmetric-ish; we symmetrize).
    pub fn new(n: usize, ms: Vec<f64>) -> Self {
        assert_eq!(ms.len(), n * n, "latency matrix shape");
        let mut m = Self { n, ms };
        m.symmetrize();
        m
    }

    /// Synthesize a geo-clustered matrix: regions are grouped into
    /// `n_clusters` "continents"; intra-cluster latency is small
    /// (1–10 ms), inter-cluster large (40–150 ms). Placing regions on a
    /// ring of cluster centroids keeps the triangle inequality
    /// approximately satisfied.
    pub fn synthesize(n_regions: usize, n_clusters: usize, rng: &mut Pcg64) -> Self {
        assert!(n_regions > 0 && n_clusters > 0);
        // 1-D coordinates: cluster centers spaced 50ms apart, members
        // jittered ±4ms around the center. Clusters are CONTIGUOUS blocks
        // of the region index space (regions 0..k are cluster 0, etc.) so
        // that tiers — whose region sets are contiguous windows (see
        // workload::generate) — span few clusters and tier distance
        // correlates with network distance, as in a real geo layout.
        let coords: Vec<f64> = (0..n_regions)
            .map(|r| {
                let c = (r * n_clusters) / n_regions;
                c as f64 * 50.0 + rng.uniform(-4.0, 4.0)
            })
            .collect();
        let mut ms = vec![0.0; n_regions * n_regions];
        for i in 0..n_regions {
            for j in 0..n_regions {
                if i != j {
                    // Distance + small propagation floor.
                    ms[i * n_regions + j] = (coords[i] - coords[j]).abs() + 1.0;
                }
            }
        }
        Self::new(n_regions, ms)
    }

    fn symmetrize(&mut self) {
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                let avg = (self.ms[i * self.n + j] + self.ms[j * self.n + i]) / 2.0;
                self.ms[i * self.n + j] = avg;
                self.ms[j * self.n + i] = avg;
            }
        }
    }

    pub fn n_regions(&self) -> usize {
        self.n
    }

    pub fn latency_ms(&self, a: RegionId, b: RegionId) -> f64 {
        self.ms[a.0 * self.n + b.0]
    }

    /// Triangle-inequality violation count (diagnostic; synthetic matrices
    /// should report 0).
    pub fn triangle_violations(&self, tolerance_ms: f64) -> usize {
        let mut v = 0;
        for a in 0..self.n {
            for b in 0..self.n {
                for c in 0..self.n {
                    let direct = self.ms[a * self.n + b];
                    let via = self.ms[a * self.n + c] + self.ms[c * self.n + b];
                    if direct > via + tolerance_ms {
                        v += 1;
                    }
                }
            }
        }
        v
    }
}

/// Latency distribution of one tier→tier transition: the cross product of
/// the source tier's regions and destination tier's regions (an app could
/// land on any pair), i.e. the paper's "source and destination tier's
/// region latency table".
pub fn transition_latencies(src: &Tier, dst: &Tier, matrix: &LatencyMatrix) -> Vec<f64> {
    let mut out = Vec::with_capacity(src.regions.len() * dst.regions.len());
    for a in src.regions.iter() {
        for b in dst.regions.iter() {
            out.push(matrix.latency_ms(a, b));
        }
    }
    out
}

/// Latency an app observes to its data source when hosted on `tier`: the
/// minimum latency from the preferred region to any of the tier's regions
/// (the region scheduler places it as close as possible).
pub fn app_tier_latency_ms(app: &App, tier: &Tier, matrix: &LatencyMatrix) -> f64 {
    tier.regions
        .iter()
        .map(|r| matrix.latency_ms(app.preferred_region, r))
        .fold(f64::INFINITY, f64::min)
}

/// Fig. 4's headline number for one balancing solution: sample each
/// transition's latency distribution `samples_per_move` times per moved
/// app (so transitions moving more apps weigh more), pool all samples
/// into one CDF, and return its p99 rounded to the closest ms.
pub const FIG4_SAMPLES: usize = 1000;

pub fn solution_p99_latency_ms(
    moves: &[Move],
    tiers: &[Tier],
    matrix: &LatencyMatrix,
    rng: &mut Pcg64,
) -> f64 {
    if moves.is_empty() {
        return 0.0;
    }
    // Group moves by (from, to) transition.
    let mut counts = std::collections::BTreeMap::<(usize, usize), usize>::new();
    for m in moves {
        *counts.entry((m.from.idx(), m.to.idx())).or_insert(0) += 1;
    }
    let total_moves = moves.len();
    let mut pooled = Vec::with_capacity(FIG4_SAMPLES);
    for (&(from, to), &n_apps) in &counts {
        let dist = Ecdf::new(transition_latencies(&tiers[from], &tiers[to], matrix));
        if dist.is_empty() {
            continue;
        }
        // Proportional sampling: FIG4_SAMPLES total, split by apps moved.
        let n_samples = (FIG4_SAMPLES * n_apps).div_ceil(total_moves);
        for _ in 0..n_samples {
            pooled.push(dist.sample(rng));
        }
    }
    let cdf = Ecdf::new(pooled);
    if cdf.is_empty() {
        0.0
    } else {
        cdf.p99().round() // "approximated to the closest ms"
    }
}

/// Mean app→data-source latency of a full assignment (used by the region
/// scheduler's accept/reject and by reporting).
pub fn assignment_mean_latency_ms(
    assignment: &Assignment,
    apps: &[App],
    tiers: &[Tier],
    matrix: &LatencyMatrix,
) -> f64 {
    if apps.is_empty() {
        return 0.0;
    }
    let total: f64 = apps
        .iter()
        .map(|app| app_tier_latency_ms(app, &tiers[assignment.tier_of(app.id).idx()], matrix))
        .sum();
    total / apps.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tier::default_ideal_utilization;
    use crate::model::{AppId, Criticality, RegionSet, ResourceVec, Slo, TierId};

    fn tier(id: usize, regions: &[usize]) -> Tier {
        Tier {
            id: TierId::from_usize(id),
            name: format!("tier{}", id + 1),
            capacity: ResourceVec::splat(100.0),
            ideal_utilization: default_ideal_utilization(),
            supported_slos: vec![Slo::Slo3],
            regions: RegionSet::from_indices(regions.iter().copied()),
        }
    }

    #[test]
    fn synthesized_matrix_is_symmetric_zero_diag() {
        let mut rng = Pcg64::new(1);
        let m = LatencyMatrix::synthesize(8, 3, &mut rng);
        for i in 0..8 {
            assert_eq!(m.latency_ms(RegionId(i), RegionId(i)), 0.0);
            for j in 0..8 {
                assert_eq!(
                    m.latency_ms(RegionId(i), RegionId(j)),
                    m.latency_ms(RegionId(j), RegionId(i))
                );
            }
        }
    }

    #[test]
    fn synthesized_matrix_respects_triangle_inequality() {
        let mut rng = Pcg64::new(2);
        let m = LatencyMatrix::synthesize(10, 3, &mut rng);
        // 1-D embedding + positive floor: allow the 1ms floor as slack.
        assert_eq!(m.triangle_violations(1.0), 0);
    }

    #[test]
    fn intra_cluster_cheaper_than_inter() {
        let mut rng = Pcg64::new(3);
        // Blocked clusters: regions 0,1 in cluster 0; 2,3 in cluster 1;
        // 4,5 in cluster 2 (n=6, 3 clusters).
        let m = LatencyMatrix::synthesize(6, 3, &mut rng);
        let intra = m.latency_ms(RegionId(0), RegionId(1));
        let inter = m.latency_ms(RegionId(0), RegionId(2));
        assert!(intra < inter, "intra {intra} < inter {inter}");
    }

    #[test]
    fn transition_latency_cross_product() {
        let mut rng = Pcg64::new(4);
        let m = LatencyMatrix::synthesize(6, 2, &mut rng);
        let a = tier(0, &[0, 1]);
        let b = tier(1, &[2, 3, 4]);
        assert_eq!(transition_latencies(&a, &b, &m).len(), 6);
    }

    #[test]
    fn p99_of_no_moves_is_zero() {
        let mut rng = Pcg64::new(5);
        let m = LatencyMatrix::synthesize(4, 2, &mut rng);
        assert_eq!(solution_p99_latency_ms(&[], &[], &m, &mut rng), 0.0);
    }

    #[test]
    fn p99_same_region_transitions_small() {
        let mut rng = Pcg64::new(6);
        let m = LatencyMatrix::synthesize(6, 2, &mut rng);
        let tiers = vec![tier(0, &[0, 1]), tier(1, &[0, 1])]; // same cluster
        let moves = vec![Move { app: AppId(0), from: TierId(0), to: TierId(1) }];
        let p = solution_p99_latency_ms(&moves, &tiers, &m, &mut rng);
        assert!(p < 20.0, "same-cluster p99 {p} should be small");
    }

    #[test]
    fn p99_cross_cluster_larger_than_intra() {
        let mut rng = Pcg64::new(7);
        // Blocked clusters: 8 regions, 4 clusters -> {0,1},{2,3},{4,5},{6,7}.
        let m = LatencyMatrix::synthesize(8, 4, &mut rng);
        let near = vec![tier(0, &[0, 1]), tier(1, &[0, 1])];
        let far = vec![tier(0, &[0, 1]), tier(1, &[6, 7])]; // 3 clusters away
        let mv = vec![Move { app: AppId(0), from: TierId(0), to: TierId(1) }];
        let p_near = solution_p99_latency_ms(&mv, &near, &m, &mut rng);
        let p_far = solution_p99_latency_ms(&mv, &far, &m, &mut rng);
        assert!(p_far > p_near + 50.0, "far {p_far} vs near {p_near}");
    }

    #[test]
    fn p99_is_integral_ms() {
        let mut rng = Pcg64::new(8);
        let m = LatencyMatrix::synthesize(6, 3, &mut rng);
        let tiers = vec![tier(0, &[0, 1]), tier(1, &[2, 5])];
        let mv = vec![
            Move { app: AppId(0), from: TierId(0), to: TierId(1) },
            Move { app: AppId(1), from: TierId(0), to: TierId(1) },
        ];
        let p = solution_p99_latency_ms(&mv, &tiers, &m, &mut rng);
        assert_eq!(p, p.round());
    }

    #[test]
    fn app_tier_latency_takes_min_over_tier_regions() {
        let mut rng = Pcg64::new(9);
        let m = LatencyMatrix::synthesize(6, 3, &mut rng);
        let app = App {
            id: AppId(0),
            name: "a".into(),
            demand: ResourceVec::ZERO,
            slo: Slo::Slo3,
            criticality: Criticality::new(0.0),
            preferred_region: RegionId(0),
        };
        let t = tier(0, &[0, 5]);
        // Region 0 is in the tier: min latency must be 0.
        assert_eq!(app_tier_latency_ms(&app, &t, &m), 0.0);
    }
}
