"""L2 graph tests: shapes, selection semantics, pallas/ref parity."""

import numpy as np
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile import model
from compile.kernels import ref
from tests.test_kernel import make_inputs


def as_jnp(inputs):
    return tuple(map(jnp.asarray, inputs))


class TestScoreAndSelect:
    def test_output_shapes(self):
        rng = np.random.default_rng(0)
        b, a, t = 64, 32, 5
        scores, loads, best_idx, best_score = model.score_and_select(
            *as_jnp(make_inputs(rng, b, a, t))
        )
        assert scores.shape == (b,)
        assert loads.shape == (b, t, ref.NUM_RESOURCES)
        assert best_idx.shape == ()
        assert best_idx.dtype == jnp.int32
        assert best_score.shape == ()

    def test_best_is_argmin(self):
        rng = np.random.default_rng(1)
        scores, _, best_idx, best_score = model.score_and_select(
            *as_jnp(make_inputs(rng, 128, 24, 4))
        )
        scores = np.asarray(scores)
        assert int(best_idx) == int(np.argmin(scores))
        assert_allclose(float(best_score), scores.min(), rtol=1e-6)

    def test_matches_reference_graph(self):
        rng = np.random.default_rng(2)
        inputs = as_jnp(make_inputs(rng, 64, 48, 5))
        gs, gl, gi, gb = model.score_and_select(*inputs)
        ws, wl, wi, wb = model.score_reference(*inputs)
        assert_allclose(np.asarray(gs), np.asarray(ws), rtol=1e-4, atol=1e-5)
        assert_allclose(np.asarray(gl), np.asarray(wl), rtol=1e-5, atol=1e-5)
        assert int(gi) == int(wi)

    def test_padded_apps_are_inert(self):
        """Zero-resource padding apps must not change any score."""
        rng = np.random.default_rng(3)
        b, a, t, pad = 16, 12, 3, 20
        assign, res, cap, ideal, init, crit, w = make_inputs(rng, b, a, t)
        # Pad apps: zero resources, zero criticality, pinned to tier 0 in
        # both candidate and incumbent (so moved == 0).
        assign_p = np.zeros((b, a + pad, t), np.float32)
        assign_p[:, :a, :] = assign
        assign_p[:, a:, 0] = 1.0
        init_p = np.zeros((a + pad, t), np.float32)
        init_p[:a] = init
        init_p[a:, 0] = 1.0
        res_p = np.zeros((a + pad, ref.NUM_RESOURCES), np.float32)
        res_p[:a] = res
        crit_p = np.zeros(a + pad, np.float32)
        crit_p[:a] = crit
        s1, _, _, _ = model.score_and_select(
            *as_jnp((assign, res, cap, ideal, init, crit, w))
        )
        s2, _, _, _ = model.score_and_select(
            *as_jnp((assign_p, res_p, cap, ideal, init_p, crit_p, w))
        )
        assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-6)
