//! Harvest side of the tracing layer: per-round merge of recorders into
//! a Chrome-trace-event JSONL file, the bounded flight-recorder ring,
//! and the merged duration histograms folded into metrics JSON.

use super::recorder::{DecisionEvent, SpanEvent, SpanRecorder};
use super::{DecisionStage, Origin, Reason, SampleKind, SpanKind, TraceLevel, N_HISTS, N_SPAN_KINDS};
use crate::util::json::Json;
use crate::util::stats::Log2Histogram;
use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

/// Rounds retained by the flight recorder.
const FLIGHT_ROUNDS: usize = 32;

/// Preallocated span capacity per flight capsule: absorbing a typical
/// round never grows the buffer, so warm steady-state rounds stay
/// allocation-free from the very first pass around the ring (a burst
/// round may still grow its capsule once; the capacity then persists).
const CAPSULE_SPANS: usize = 512;

/// Preallocated decision capacity per flight capsule.
const CAPSULE_DECISIONS: usize = 1024;

/// What tripped a flight-recorder dump. Each trigger kind dumps at most
/// once per run (the first occurrence is the interesting one; repeats
/// would overwrite it with a later, less relevant window).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlightTrigger {
    /// A round left one or more tiers over their SLO capacity.
    SloBreach = 0,
    /// The ingest queue shed events at the door this round.
    ShedBurst = 1,
    /// A snapshot failed its restore integrity check.
    SnapshotCorrupt = 2,
    /// The process panicked (dump written from the panic hook).
    Panic = 3,
}

/// Number of flight-trigger kinds.
const N_TRIGGERS: usize = 4;

impl FlightTrigger {
    /// File-name fragment and JSON name of this trigger.
    pub fn name(self) -> &'static str {
        match self {
            FlightTrigger::SloBreach => "slo_breach",
            FlightTrigger::ShedBurst => "shed_burst",
            FlightTrigger::SnapshotCorrupt => "snapshot_corrupt",
            FlightTrigger::Panic => "panic",
        }
    }
}

/// One retained round of spans + decisions.
#[derive(Debug)]
struct Capsule {
    round: u32,
    used: bool,
    spans: Vec<SpanEvent>,
    decisions: Vec<DecisionEvent>,
}

/// Bounded ring of the last [`FLIGHT_ROUNDS`] rounds' events, dumped to
/// disk when a [`FlightTrigger`] fires. Shared behind `Arc<Mutex<..>>`
/// so the panic hook can dump it from any thread.
#[derive(Debug)]
pub struct FlightRecorder {
    capsules: Vec<Capsule>,
    /// Index of the capsule currently being filled.
    head: usize,
}

impl FlightRecorder {
    fn new() -> Self {
        let capsules = (0..FLIGHT_ROUNDS)
            .map(|_| Capsule {
                round: 0,
                used: false,
                spans: Vec::with_capacity(CAPSULE_SPANS),
                decisions: Vec::with_capacity(CAPSULE_DECISIONS),
            })
            .collect();
        Self { capsules, head: 0 }
    }

    /// Recycle the head capsule lazily: the first absorb of a new round
    /// clears whatever the ring held K rounds ago, so retention is the
    /// full K rounds (clearing eagerly on seal would cost one).
    fn recycle_head(&mut self) {
        let c = &mut self.capsules[self.head];
        if c.used {
            c.used = false;
            c.spans.clear();
            c.decisions.clear();
        }
    }

    fn absorb(&mut self, spans: &[SpanEvent], decisions: &[DecisionEvent]) {
        self.recycle_head();
        let c = &mut self.capsules[self.head];
        c.spans.extend_from_slice(spans);
        c.decisions.extend_from_slice(decisions);
    }

    fn seal_round(&mut self, round: u32) {
        self.recycle_head();
        let c = &mut self.capsules[self.head];
        c.round = round;
        c.used = true;
        self.head = (self.head + 1) % self.capsules.len();
    }

    /// Serialize the retained window (oldest round first) for a dump.
    pub fn to_json(&self, trigger: FlightTrigger, note: &str) -> Json {
        let mut idx: Vec<usize> =
            (0..self.capsules.len()).filter(|&i| self.capsules[i].used).collect();
        idx.sort_by_key(|&i| self.capsules[i].round);
        let rounds = idx.into_iter().map(|i| {
            let c = &self.capsules[i];
            Json::obj(vec![
                ("round", Json::num(c.round as f64)),
                (
                    "spans",
                    Json::arr(c.spans.iter().map(|s| {
                        Json::obj(vec![
                            ("track", Json::num(s.track as f64)),
                            ("name", Json::str(SpanKind::from_u8(s.kind).name())),
                            ("phase", Json::str(if s.phase == 0 { "B" } else { "E" })),
                            ("ts", Json::num(s.ts() as f64)),
                        ])
                    })),
                ),
                ("decisions", Json::arr(c.decisions.iter().map(decision_json))),
            ])
        });
        Json::obj(vec![
            ("kind", Json::str("flight_recorder_dump")),
            ("trigger", Json::str(trigger.name())),
            ("note", Json::str(note)),
            ("retained_rounds", Json::num(FLIGHT_ROUNDS as f64)),
            ("rounds", Json::arr(rounds)),
        ])
    }

    /// Write a dump file for `trigger` next to the trace at `path`.
    pub fn dump(&self, path: &Path, trigger: FlightTrigger, note: &str) -> std::io::Result<()> {
        let mut out = self.to_json(trigger, note).pretty();
        out.push('\n');
        std::fs::write(path, out)
    }
}

fn decision_json(d: &DecisionEvent) -> Json {
    Json::obj(vec![
        ("track", Json::num(d.track as f64)),
        ("stage", Json::str(DecisionStage::from_u8(d.stage).name())),
        ("origin", Json::str(Origin::from_u8(d.origin).name())),
        ("reason", Json::str(Reason::from_u8(d.reason).name())),
        ("round", Json::num(d.round as f64)),
        ("app", Json::num(d.app as f64)),
        ("from", Json::num(d.from as f64)),
        ("to", Json::num(d.to as f64)),
        ("detail", Json::num(d.detail)),
    ])
}

/// Buffered Chrome-trace-event JSONL writer. The output is a JSON array
/// opened with `[` whose elements sit one per line with trailing commas
/// and no closing bracket — exactly the truncation-tolerant form
/// Perfetto and `chrome://tracing` load, and trivially greppable line
/// by line. All formatting goes through one reused `String` scratch so
/// steady-state writes never allocate.
struct TraceWriter {
    out: BufWriter<File>,
    line: String,
}

impl TraceWriter {
    fn create(path: &Path) -> std::io::Result<Self> {
        let file = File::create(path)?;
        let mut out = BufWriter::with_capacity(1 << 16, file);
        out.write_all(b"[\n")?;
        out.write_all(
            b"{\"ph\":\"M\",\"pid\":0,\"name\":\"process_name\",\
              \"args\":{\"name\":\"sptlb\"}},\n",
        )?;
        Ok(Self { out, line: String::with_capacity(512) })
    }

    fn write_span(&mut self, s: &SpanEvent) -> std::io::Result<()> {
        self.line.clear();
        let name = SpanKind::from_u8(s.kind).name();
        if s.phase == 0 {
            let _ = write!(
                self.line,
                "{{\"ph\":\"B\",\"pid\":0,\"tid\":{},\"ts\":{},\"name\":\"{}\",\
                 \"args\":{{\"round\":{}}}}},",
                s.track,
                s.ts(),
                name,
                s.round
            );
        } else {
            let _ = write!(
                self.line,
                "{{\"ph\":\"E\",\"pid\":0,\"tid\":{},\"ts\":{},\"name\":\"{}\"}},",
                s.track,
                s.ts(),
                name
            );
        }
        self.line.push('\n');
        self.out.write_all(self.line.as_bytes())
    }

    fn write_decision(&mut self, d: &DecisionEvent) -> std::io::Result<()> {
        self.line.clear();
        let _ = write!(
            self.line,
            "{{\"ph\":\"i\",\"pid\":0,\"tid\":{},\"ts\":{},\"s\":\"t\",\"name\":\"decision\",\
             \"args\":{{\"stage\":\"{}\",\"origin\":\"{}\",\"reason\":\"{}\",\"round\":{},\
             \"app\":{},\"from\":{},\"to\":{},\"detail\":{}}}}},",
            d.track,
            d.ts(),
            DecisionStage::from_u8(d.stage).name(),
            Origin::from_u8(d.origin).name(),
            Reason::from_u8(d.reason).name(),
            d.round,
            d.app,
            d.from,
            d.to,
            // JSON has no NaN/Inf; clamp non-finite payloads to 0.
            if d.detail.is_finite() { d.detail } else { 0.0 }
        );
        self.line.push('\n');
        self.out.write_all(self.line.as_bytes())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Per-owner tracing hub: makes the owner's [`SpanRecorder`]s, harvests
/// them once per round in a fixed order, writes the trace file, feeds
/// the flight ring, and accumulates the merged duration histograms.
pub struct ObsHub {
    level: TraceLevel,
    writer: Option<TraceWriter>,
    trace_path: Option<PathBuf>,
    flight: Arc<Mutex<FlightRecorder>>,
    hists: [Log2Histogram; N_HISTS],
    dropped: u64,
    dumped: [bool; N_TRIGGERS],
    io_error: bool,
}

impl std::fmt::Debug for ObsHub {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ObsHub")
            .field("level", &self.level)
            .field("trace_path", &self.trace_path)
            .field("dropped", &self.dropped)
            .finish()
    }
}

impl ObsHub {
    /// A hub writing the trace to `path` at `level`. With `path = None`
    /// spans/decisions still feed the flight ring and histograms but no
    /// trace file is written.
    pub fn new(level: TraceLevel, path: Option<&Path>) -> std::io::Result<Self> {
        let writer = match path {
            Some(p) => Some(TraceWriter::create(p)?),
            None => None,
        };
        Ok(Self {
            level,
            writer,
            trace_path: path.map(Path::to_path_buf),
            flight: Arc::new(Mutex::new(FlightRecorder::new())),
            hists: super::hist_array(),
            dropped: 0,
            dumped: [false; N_TRIGGERS],
            io_error: false,
        })
    }

    /// The hub's trace level.
    pub fn level(&self) -> TraceLevel {
        self.level
    }

    /// A new preallocated recorder for `track` at the hub's level.
    pub fn recorder(&self, track: u16) -> SpanRecorder {
        SpanRecorder::new(self.level, track)
    }

    /// Shared flight ring + dump-path base, for the panic hook.
    pub fn flight_handle(&self) -> (Arc<Mutex<FlightRecorder>>, Option<PathBuf>) {
        (Arc::clone(&self.flight), self.trace_path.clone())
    }

    /// Drain one recorder's events into the trace file and the current
    /// flight capsule, merge its histograms, and clear it. Call once
    /// per recorder per round, in a fixed (track) order.
    pub fn harvest(&mut self, rec: &mut SpanRecorder) {
        if let Some(w) = self.writer.as_mut() {
            for s in rec.spans() {
                if w.write_span(s).is_err() {
                    self.io_error = true;
                    break;
                }
            }
            for d in rec.decisions() {
                if w.write_decision(d).is_err() {
                    self.io_error = true;
                    break;
                }
            }
        }
        if let Ok(mut flight) = self.flight.lock() {
            flight.absorb(rec.spans(), rec.decisions());
        }
        for (acc, h) in self.hists.iter_mut().zip(rec.hists()) {
            acc.merge(h);
        }
        self.dropped += rec.dropped();
        rec.clear();
        rec.clear_hists();
    }

    /// Seal the flight capsule for `round` and flush the trace file.
    pub fn commit_round(&mut self, round: u32) {
        if let Ok(mut flight) = self.flight.lock() {
            flight.seal_round(round);
        }
        if let Some(w) = self.writer.as_mut() {
            if w.flush().is_err() {
                self.io_error = true;
            }
        }
    }

    /// Fire a flight trigger: dump the retained window to
    /// `<trace>.flight-<trigger>.json`, at most once per trigger kind.
    pub fn trigger(&mut self, trigger: FlightTrigger, note: &str) {
        if self.dumped[trigger as usize] {
            return;
        }
        self.dumped[trigger as usize] = true;
        if let (Some(base), Ok(flight)) = (self.trace_path.as_ref(), self.flight.lock()) {
            let path = flight_dump_path(base, trigger);
            if let Err(e) = flight.dump(&path, trigger, note) {
                eprintln!("flight dump failed ({}): {e}", path.display());
            }
        }
    }

    /// Merged per-span-kind duration histograms plus free-form value
    /// histograms as metrics JSON (telemetry: percentiles are log2
    /// bucket lower bounds — ns for spans, domain units for samples).
    pub fn metrics_json(&self) -> Json {
        let spans = (0..N_SPAN_KINDS).filter_map(|i| {
            let h = &self.hists[i];
            if h.is_empty() {
                return None;
            }
            Some((
                SpanKind::from_u8(i as u8).name(),
                Json::obj(vec![
                    ("count", Json::num(h.count() as f64)),
                    ("p50_ns", Json::num(h.p50() as f64)),
                    ("p95_ns", Json::num(h.p95() as f64)),
                    ("p99_ns", Json::num(h.p99() as f64)),
                ]),
            ))
        });
        let samples = (0..super::N_SAMPLE_KINDS).filter_map(|i| {
            let h = &self.hists[N_SPAN_KINDS + i];
            if h.is_empty() {
                return None;
            }
            Some((
                SampleKind::from_u8(i as u8).name(),
                Json::obj(vec![
                    ("count", Json::num(h.count() as f64)),
                    ("p50", Json::num(h.p50() as f64)),
                    ("p95", Json::num(h.p95() as f64)),
                    ("p99", Json::num(h.p99() as f64)),
                ]),
            ))
        });
        Json::obj(vec![
            ("level", Json::str(self.level.name())),
            ("dropped_events", Json::num(self.dropped as f64)),
            ("spans", Json::obj(spans.collect())),
            ("samples", Json::obj(samples.collect())),
        ])
    }

    /// Whether any trace write failed (the run keeps going; the trace
    /// is best-effort by design).
    pub fn had_io_error(&self) -> bool {
        self.io_error
    }
}

/// Dump path for `trigger` derived from the trace path.
pub fn flight_dump_path(trace: &Path, trigger: FlightTrigger) -> PathBuf {
    let mut name = trace.file_name().unwrap_or_default().to_os_string();
    name.push(format!(".flight-{}.json", trigger.name()));
    trace.with_file_name(name)
}

type PanicFlight = (Arc<Mutex<FlightRecorder>>, PathBuf);

static PANIC_FLIGHT: Mutex<Option<PanicFlight>> = Mutex::new(None);
static PANIC_HOOK: OnceLock<()> = OnceLock::new();

/// Arm the process-wide panic hook to dump the given flight ring on
/// panic. The hook is installed once (chaining the default hook); the
/// armed ring can be replaced by later calls.
pub fn arm_panic_hook(flight: Arc<Mutex<FlightRecorder>>, trace_path: &Path) {
    if let Ok(mut slot) = PANIC_FLIGHT.lock() {
        *slot = Some((flight, trace_path.to_path_buf()));
    }
    PANIC_HOOK.get_or_init(|| {
        let previous = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if let Ok(slot) = PANIC_FLIGHT.lock() {
                if let Some((flight, base)) = slot.as_ref() {
                    if let Ok(f) = flight.lock() {
                        let path = flight_dump_path(base, FlightTrigger::Panic);
                        let note = info.to_string();
                        let _ = f.dump(&path, FlightTrigger::Panic, &note);
                    }
                }
            }
            previous(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::super::{Decision, DecisionStage, Origin, Reason};
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("sptlb_obs_{}_{}", name, std::process::id()))
    }

    #[test]
    fn hub_writes_trace_lines_and_histograms() {
        let path = tmp("hub");
        let mut hub = ObsHub::new(TraceLevel::Decisions, Some(&path)).unwrap();
        let mut rec = hub.recorder(0);
        rec.set_round(3);
        rec.begin(SpanKind::RegionRound);
        rec.begin(SpanKind::Solve);
        rec.end(SpanKind::Solve);
        rec.decision(Decision {
            stage: DecisionStage::Adopted,
            origin: Origin::Engine,
            reason: Reason::None,
            app: 9,
            from: 0,
            to: 2,
            detail: 0.0,
        });
        rec.end(SpanKind::RegionRound);
        hub.harvest(&mut rec);
        hub.commit_round(3);
        assert!(!hub.had_io_error());
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("[\n"));
        assert!(text.contains("\"name\":\"solve\""));
        assert!(text.contains("\"name\":\"decision\""));
        assert!(text.contains("\"stage\":\"adopted\""));
        assert!(text.contains("\"ts\":3000000"));
        // Recorder drained; histograms merged into the hub.
        assert!(rec.spans().is_empty());
        let m = hub.metrics_json();
        assert!(m.get("spans").get("solve").get("count").as_u64() == Some(1));
        assert_eq!(m.get("dropped_events").as_u64(), Some(0));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn flight_ring_retains_last_k_rounds_and_dumps_once() {
        let path = tmp("flight");
        let mut hub = ObsHub::new(TraceLevel::Decisions, Some(&path)).unwrap();
        let mut rec = hub.recorder(0);
        for round in 0..40u32 {
            rec.set_round(round);
            rec.begin(SpanKind::RegionRound);
            rec.end(SpanKind::RegionRound);
            hub.harvest(&mut rec);
            hub.commit_round(round);
        }
        hub.trigger(FlightTrigger::SloBreach, "test breach");
        hub.trigger(FlightTrigger::SloBreach, "second breach (ignored)");
        let dump_path = flight_dump_path(&path, FlightTrigger::SloBreach);
        let dump = std::fs::read_to_string(&dump_path).unwrap();
        let j = Json::parse(&dump).unwrap();
        assert_eq!(j.get("trigger").as_str(), Some("slo_breach"));
        assert_eq!(j.get("note").as_str(), Some("test breach"));
        let rounds = j.get("rounds").as_arr().unwrap();
        assert_eq!(rounds.len(), FLIGHT_ROUNDS, "ring keeps exactly K rounds");
        // Oldest retained round is 40 - K (the ring dropped the rest).
        assert_eq!(rounds[0].get("round").as_u64(), Some(40 - FLIGHT_ROUNDS as u64));
        assert_eq!(rounds.last().unwrap().get("round").as_u64(), Some(39));
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_file(&dump_path).unwrap();
    }

    #[test]
    fn hub_without_path_still_accumulates() {
        let mut hub = ObsHub::new(TraceLevel::Spans, None).unwrap();
        let mut rec = hub.recorder(0);
        rec.begin(SpanKind::Solve);
        rec.end(SpanKind::Solve);
        hub.harvest(&mut rec);
        hub.commit_round(0);
        assert_eq!(hub.metrics_json().get("spans").get("solve").get("count").as_u64(), Some(1));
        // No trace path: triggers are a no-op rather than an error.
        hub.trigger(FlightTrigger::ShedBurst, "no-op");
    }
}
