//! `sptlb` — leader entrypoint + CLI.
//!
//! Subcommands:
//!   balance   one-shot balancing run on a workload preset; prints the
//!             §3.3 report (projected mapping, metrics, validation).
//!   serve     run the coordinator leader loop for N rounds (drifting
//!             workload, decision log, service metrics). With --ingest,
//!             run the async ingest-plane service runtime instead:
//!             producer threads feed a bounded queue, rounds batch under
//!             a latency budget, and the run is journaled + snapshotted
//!             so a killed process restores and replays bit-identically.
//!             With --ingest --regions N > 1 each region gets its own
//!             queue drained by a pinned fabric worker, and the journal
//!             and snapshot are region-tagged.
//!   fig3      regenerate Figure 3 (a/b/c) tables for a preset.
//!   sweep     regenerate the Fig. 4/5 variant×solver×timeout sweep.
//!   check     verify the AOT artifacts load and match the rust scorer.
//!   bench     solution-quality harnesses; `bench gap` measures the
//!             LocalSearch optimality gap against exact optima and
//!             writes GAP_report.json (the CI gap-gate input).
//!   explain   reconstruct an app's decision provenance (propose → vet
//!             → avoid → escalate chain) from a `serve --trace` JSONL.
//!
//! Every command returns `Result<(), sptlb::service::Error>`; the exit
//! code is derived in exactly one place (the bottom of [`main`]) via
//! `Error::exit_code`. Flag parsing feeds the [`ServiceConfig`] builder
//! at a single point ([`build_service_config`]), so invalid knob
//! combinations surface as typed `ConfigError`s, not scattered
//! `eprintln!`s.

use sptlb::coordinator::{Coordinator, FleetState, MultiRegionCoordinator};
use sptlb::metadata::MetadataStore;
use sptlb::obs::{self, FlightTrigger, ObsHub, TraceLevel};
use sptlb::report;
use sptlb::service::{
    append_journal_round, append_multi_journal_round, load_journal, load_multi_journal, ConfigError,
    Error, MultiRegionService, MultiSnapshot, ScenarioProducer, Service, ServiceConfig, Snapshot,
};
use sptlb::sptlb::Sptlb;
use sptlb::util::cli::{CliError, Command, Parsed};
use sptlb::util::json::Json;
use sptlb::workload::{
    generate, generate_multiregion, MultiRegionScenario, MultiRegionSpec, ScenarioConfig, TestBed,
    WorkloadSpec,
};
use std::time::Duration;

fn main() {
    sptlb::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(|s| s.as_str()) {
        Some("balance") => cmd_balance(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("fig3") => cmd_fig3(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("check") => cmd_check(&args[1..]),
        Some("bench") => cmd_bench(&args[1..]),
        Some("explain") => cmd_explain(&args[1..]),
        Some("--help") | Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            print_help();
            Err(Error::Usage(format!("unknown subcommand '{other}'")))
        }
    };
    // The single exit-code mapping: usage/config mistakes exit 2,
    // runtime failures exit 1, success exits 0.
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(e.exit_code());
    }
}

fn print_help() {
    println!(
        "sptlb — Stream-Processing Tier Load Balancer (paper reproduction)\n\
         \n\
         USAGE: sptlb <balance|serve|fig3|sweep|check|bench|explain> [options]\n\
         \n\
         Run `sptlb <subcommand> --help` for per-command options."
    );
}

/// Lift a CLI parse error into the crate error surface.
fn usage(e: CliError) -> Error {
    Error::Usage(e.to_string())
}

fn load_bed(scenario: &str, seed: u64) -> Result<TestBed, Error> {
    WorkloadSpec::by_name(scenario)
        .map(|s| generate(&s.with_seed(seed)))
        .ok_or_else(|| ConfigError::UnknownWorkload(scenario.to_string()).into())
}

/// The `--events` preset list for error messages and `--events help`,
/// derived from the presets themselves so it cannot drift from the code.
fn event_preset_list(multiregion: bool) -> String {
    let mut names: Vec<&str> = Vec::new();
    if multiregion {
        names.extend(MultiRegionScenario::PRESETS);
    }
    names.extend(ScenarioConfig::PRESETS);
    names.join("|")
}

fn with_parsed(
    cmd: Command,
    args: &[String],
    run: impl FnOnce(Parsed) -> Result<(), Error>,
) -> Result<(), Error> {
    match cmd.parse(args) {
        Ok(p) if p.flag("help") => {
            println!("{}", cmd.usage());
            Ok(())
        }
        Ok(p) => run(p),
        Err(e) => Err(Error::Usage(format!("{e}\n\n{}", cmd.usage()))),
    }
}

/// Write each `(--flag, json)` pair whose flag was given a path.
fn write_logs(p: &Parsed, outs: &[(&str, Json)]) -> Result<(), Error> {
    for (flag, json) in outs {
        if let Some(path) = p.get(flag).filter(|v| !v.is_empty()) {
            std::fs::write(path, json.pretty())?;
            println!("{flag} written to {path}");
        }
    }
    Ok(())
}

fn cmd_balance(args: &[String]) -> Result<(), Error> {
    let cmd = Command::new("balance", "one-shot balancing run")
        .opt("scenario", "paper", "workload preset (paper|small|large)")
        .opt("seed", "42", "prng seed")
        .opt("solver", "local", "solver (local|optimal)")
        .opt("variant", "manual_cnst", "integration variant (no|w|manual)")
        .opt("timeout-ms", "100", "solver deadline in ms")
        .opt("movement", "0.10", "movement fraction (C3)")
        .opt("workers", "1", "local-search worker threads (sharded scan)")
        .opt("shard", "apps", "move-space shard strategy (apps|moves)")
        .opt("out", "", "write the full JSON report to this file")
        .flag("json", "print the JSON report to stdout");
    with_parsed(cmd, args, |p| {
        let config = ServiceConfig::builder()
            .workload(p.str("scenario").map_err(usage)?)
            .seed(p.u64("seed").map_err(usage)?)
            .solver(p.str("solver").map_err(usage)?)
            .variant(p.str("variant").map_err(usage)?)
            .timeout(Duration::from_millis(p.u64("timeout-ms").map_err(usage)?))
            .movement_fraction(p.f64("movement").map_err(usage)?)
            .workers(p.usize("workers").map_err(usage)?)
            .shard(p.str("shard").map_err(usage)?)
            .build()?;
        let scenario = config.workload_name.clone();
        let bed = generate(&config.workload);
        let store = MetadataStore::from_apps(bed.apps.clone()).expect("unique ids");
        let report =
            Sptlb::new(config.sptlb()).balance(&store, &bed.tiers, &bed.latency, &bed.initial);

        let moves = report.solution.moves(&report.problem);
        println!(
            "scenario={scenario} apps={} tiers={} | {} moves, score {:.4}, p99 {:.0}ms, pipeline {:.0}ms",
            bed.apps.len(),
            bed.tiers.len(),
            moves.len(),
            report.solution.score,
            report.p99_latency_ms,
            report.pipeline_ms,
        );
        for (i, u) in report.projected_utilization.iter().enumerate() {
            println!(
                "  tier{}: cpu {:5.1}%  mem {:5.1}%  tasks {:5.1}%",
                i + 1,
                u.cpu() * 100.0,
                u.mem() * 100.0,
                u.tasks() * 100.0
            );
        }
        if !report.violations.is_empty() {
            println!("violations:");
            for v in &report.violations {
                println!("  - {v}");
            }
        }
        let j = report.to_json();
        if p.flag("json") {
            println!("{}", j.pretty());
        }
        if let Some(path) = p.get("out").filter(|v| !v.is_empty()) {
            std::fs::write(path, j.pretty())?;
            println!("report written to {path}");
        }
        Ok(())
    })
}

/// Parse the shared serve flags into the one validated [`ServiceConfig`]
/// — the single point where CLI strings meet the builder.
fn build_service_config(p: &Parsed) -> Result<ServiceConfig, Error> {
    let mut b = ServiceConfig::builder()
        .workload(p.str("scenario").map_err(usage)?)
        .events(p.str("events").map_err(usage)?)
        .seed(p.u64("seed").map_err(usage)?)
        .rounds(p.u64("rounds").map_err(usage)? as u32)
        .timeout(Duration::from_millis(p.u64("timeout-ms").map_err(usage)?))
        .engine(p.str("engine").map_err(usage)?)
        .avoid_decay(p.u64("decay").map_err(usage)? as u32)
        .forecaster(p.str("forecaster").map_err(usage)?)
        .horizon(p.u64("horizon").map_err(usage)? as u32)
        .history(p.usize("history").map_err(usage)?)
        .period(p.u64("period").map_err(usage)? as u32)
        .workers(p.usize("workers").map_err(usage)?)
        .shard(p.str("shard").map_err(usage)?)
        .regions(p.usize("regions").map_err(usage)?)
        .region_exec(p.str("region-exec").map_err(usage)?)
        .backpressure(p.str("backpressure").map_err(usage)?)
        .queue_capacity(p.usize("queue").map_err(usage)?)
        .batch_budget(Duration::from_millis(p.u64("batch-ms").map_err(usage)?))
        .max_batch(p.usize("max-batch").map_err(usage)?)
        .snapshot_every(p.u64("snapshot-every").map_err(usage)? as u32);
    // Empty-string defaults mean "not set": the builder rejects
    // multi-region-only options with --regions 1, so they must only be
    // forwarded when the user actually typed them.
    if let Some(v) = p.get("global-policy").filter(|v| !v.is_empty()) {
        b = b.global_policy(v.to_string());
    }
    if p.get("global-avoid-decay").is_some_and(|v| !v.is_empty()) {
        b = b.global_avoid_decay(p.u64("global-avoid-decay").map_err(usage)? as u32);
    }
    if p.get("drift").is_some_and(|v| !v.is_empty()) {
        b = b.drift_sigma(p.f64("drift").map_err(usage)?);
    }
    if p.get("drift-frac").is_some_and(|v| !v.is_empty()) {
        b = b.drift_fraction(p.f64("drift-frac").map_err(usage)?);
    }
    if p.get("arrivals").is_some_and(|v| !v.is_empty()) {
        b = b.arrival_prob(p.f64("arrivals").map_err(usage)?);
    }
    if p.get("departures").is_some_and(|v| !v.is_empty()) {
        b = b.departure_prob(p.f64("departures").map_err(usage)?);
    }
    Ok(b.build()?)
}

/// Build the trace/flight-recorder hub from `--trace`/`--trace-level`
/// and arm the panic hook so a crash dumps the retained round window
/// next to the trace file. Returns `None` when tracing is disarmed
/// (no `--trace` path and no explicit level, or `--trace-level off`).
fn build_obs_hub(p: &Parsed) -> Result<Option<ObsHub>, Error> {
    let path = p.str("trace").map_err(usage)?;
    let level_arg = p.str("trace-level").map_err(usage)?;
    let level = if level_arg.is_empty() {
        // A bare `--trace <path>` records spans; decisions are opt-in.
        if path.is_empty() {
            return Ok(None);
        }
        TraceLevel::Spans
    } else {
        TraceLevel::parse(&level_arg).ok_or_else(|| {
            Error::Usage(format!(
                "unknown --trace-level '{level_arg}' (off|rounds|spans|decisions)"
            ))
        })?
    };
    if level == TraceLevel::Off {
        return Ok(None);
    }
    let path = (!path.is_empty()).then(|| std::path::PathBuf::from(&path));
    let hub = ObsHub::new(level, path.as_deref())?;
    if let (flight, Some(trace)) = hub.flight_handle() {
        obs::arm_panic_hook(flight, &trace);
    }
    Ok(Some(hub))
}

/// Warn (without failing the run) if any trace write errored — the
/// trace is best-effort telemetry, never a reason to lose a run.
fn warn_trace_io(hub: Option<&ObsHub>) {
    if hub.is_some_and(ObsHub::had_io_error) {
        eprintln!("warning: some trace writes failed; the trace file is incomplete");
    }
}

fn cmd_serve(args: &[String]) -> Result<(), Error> {
    let cmd = Command::new("serve", "run the coordinator leader loop")
        .opt("scenario", "paper", "workload preset (paper|small|large)")
        .opt(
            "events",
            "drift",
            "event scenario (steady|drift|churn|spike|outage|mixed|diurnal|burst; with --regions also multiregion|failover; 'help' lists)",
        )
        .opt("seed", "42", "prng seed")
        .opt("rounds", "10", "balancing rounds to run")
        .opt("timeout-ms", "60", "per-round solver deadline")
        .opt("engine", "incremental", "round engine (incremental|rebuild)")
        .opt(
            "decay",
            "0",
            "rounds a protocol avoid-constraint persists (SPTLB-level edges in the shared \
             coop::AvoidRegistry kernel; see --global-avoid-decay for the level above)",
        )
        .opt(
            "global-avoid-decay",
            "",
            "rounds a rejected cross-region migration stays avoided (global-level edges in the \
             same coop::AvoidRegistry kernel as --decay; default: the --global-policy preset's \
             value; only meaningful with --regions > 1)",
        )
        .opt(
            "forecaster",
            "none",
            "load forecaster feeding every scheduler layer (none|naive-last|ewma|holt|seasonal-naive)",
        )
        .opt("horizon", "3", "forecast horizon in rounds (>= 1)")
        .opt("history", "32", "per-app demand-history window in observations (>= 2)")
        .opt("period", "12", "seasonal-naive season length in observations (match the wave period; >= 1)")
        .opt("drift", "", "override: demand drift sigma")
        .opt("drift-frac", "", "override: fraction of apps drifting per round")
        .opt("arrivals", "", "override: per-round app arrival probability")
        .opt("departures", "", "override: per-round app departure probability")
        .opt("workers", "1", "local-search worker threads (sharded scan)")
        .opt("shard", "apps", "move-space shard strategy (apps|moves)")
        .opt("regions", "1", "global regions (each runs its own SPTLB; >1 enables the global layer)")
        .opt(
            "global-policy",
            "",
            "cross-region policy (none|spillover|aggressive; default spillover; requires --regions > 1)",
        )
        .opt("region-exec", "parallel", "per-region round execution (sequential|parallel)")
        .flag(
            "ingest",
            "run the async ingest-plane runtime (producers -> queue -> batched solves); with \
             --regions N > 1 each region drains its own queue on a pinned worker fabric",
        )
        .opt("queue", "1024", "per-queue ingest capacity in events (with --ingest)")
        .opt("batch-ms", "5", "per-round batch latency budget in ms (with --ingest)")
        .opt("max-batch", "256", "max events per batched solve (with --ingest)")
        .opt("producers", "1", "scenario producer threads, per region (with --ingest)")
        .opt("backpressure", "shed", "producer policy on a full queue (shed|block; with --ingest)")
        .opt("snapshot-dir", "", "write snapshot.json + journal.jsonl here (with --ingest)")
        .opt("snapshot-every", "8", "snapshot every K journaled rounds (0 = final only; with --ingest)")
        .flag("restore", "resume from <snapshot-dir>/snapshot.json before ingesting")
        .opt("log", "", "write the decision log JSON to this file")
        .opt("event-log", "", "write the applied-events journal JSON to this file")
        .opt("trace", "", "write a Chrome-trace-event JSONL (Perfetto-loadable) to this file")
        .opt(
            "trace-level",
            "",
            "tracing detail: off|rounds|spans|decisions (default with --trace: spans)",
        );
    with_parsed(cmd, args, |p| {
        // `--scenario help` / `--events help`: enumerate the valid preset
        // names instead of erroring (the lists are derived from the
        // presets themselves, so they always include new additions).
        if p.str("scenario").map_err(usage)? == "help" {
            println!("workload presets: {}", WorkloadSpec::PRESETS.join("|"));
            return Ok(());
        }
        if p.get("events") == Some("help") {
            println!("event scenarios: {}", event_preset_list(false));
            println!(
                "with --regions N > 1 also: {}",
                MultiRegionScenario::PRESETS.join("|")
            );
            return Ok(());
        }
        let config = build_service_config(&p)?;
        if p.flag("ingest") {
            return if config.regions > 1 {
                cmd_serve_ingest_multi(&p, config)
            } else {
                cmd_serve_ingest(&p, config)
            };
        }
        if config.regions > 1 {
            return cmd_serve_multiregion(&p, config);
        }
        let bed = generate(&config.workload);
        let mut coordinator = Coordinator::from_testbed(config.coordinator(), bed);
        if let Some(hub) = build_obs_hub(&p)? {
            coordinator.attach_obs(hub);
        }
        coordinator.run(config.rounds);
        println!("{}", coordinator.metrics_json().pretty());
        warn_trace_io(coordinator.obs_hub());
        write_logs(
            &p,
            &[
                ("log", coordinator.log_json()),
                ("event-log", coordinator.event_log_json()),
            ],
        )
    })
}

/// `serve --regions N` (N > 1): the global scheduler over N per-region
/// SPTLBs, each solving in parallel on its own worker thread.
fn cmd_serve_multiregion(p: &Parsed, config: ServiceConfig) -> Result<(), Error> {
    let bed = generate_multiregion(
        &MultiRegionSpec::new(config.regions, config.workload.clone()).with_seed(config.seed),
    );
    let mut coordinator = MultiRegionCoordinator::new(config.multiregion(), bed);
    if let Some(hub) = build_obs_hub(p)? {
        coordinator.attach_obs(hub);
    }
    coordinator.run(config.rounds);
    println!("{}", coordinator.metrics_json().pretty());
    warn_trace_io(coordinator.obs_hub());
    write_logs(
        p,
        &[
            ("log", coordinator.log_json()),
            ("event-log", coordinator.event_log_json()),
        ],
    )
}

/// `serve --ingest`: the async ingest-plane service runtime. Scenario
/// producer threads submit events through cloned handles into the
/// bounded queue; the consumer loop drains under the batch latency
/// budget, admits, journals, solves, and periodically snapshots — so a
/// killed process restores with `--restore` and the journal replays
/// bit-identically offline.
fn cmd_serve_ingest(p: &Parsed, config: ServiceConfig) -> Result<(), Error> {
    let producers = p.usize_at_least("producers", 1).map_err(usage)?;
    let dir = p.str("snapshot-dir").map_err(usage)?;
    let dir = (!dir.is_empty()).then(|| std::path::PathBuf::from(dir));
    let rounds = config.rounds;
    let snapshot_every = config.snapshot_every;
    // The hub exists before restore so a corrupt snapshot/journal fires
    // the flight trigger (dumping whatever the ring held) on the way out.
    let mut hub = build_obs_hub(p)?;

    let mut service = if p.flag("restore") {
        let Some(dir) = dir.as_ref() else {
            return Err(Error::Usage("--restore requires --snapshot-dir".into()));
        };
        let restored = (|| {
            let snap =
                Snapshot::load(&dir.join("snapshot.json"))?.map_err(Error::SnapshotCorrupt)?;
            let journal =
                load_journal(&dir.join("journal.jsonl"))?.map_err(Error::SnapshotCorrupt)?;
            let service = Service::restore(config, &snap, &journal)?;
            Ok::<_, Error>((snap.rounds_done, service))
        })();
        match restored {
            Ok((snap_rounds, service)) => {
                println!(
                    "restored from snapshot at round {} (+{} journal tail round(s) replayed)",
                    snap_rounds,
                    service.rounds_done() - snap_rounds
                );
                service
            }
            Err(e) => {
                if let (Error::SnapshotCorrupt(_), Some(h)) = (&e, hub.as_mut()) {
                    h.trigger(FlightTrigger::SnapshotCorrupt, &e.to_string());
                }
                return Err(e);
            }
        }
    } else {
        Service::new(config)
    };
    if let Some(hub) = hub.take() {
        service.attach_obs(hub);
    }

    // Open the on-disk journal. It is rewritten from the verified
    // in-memory journal rather than opened in append mode: a torn tail
    // line (dropped during load) has no trailing newline, so appending
    // after it would corrupt the first new round.
    let mut journal_file = match dir.as_ref() {
        Some(dir) => {
            std::fs::create_dir_all(dir)?;
            let mut f = std::fs::File::create(dir.join("journal.jsonl"))?;
            for k in 0..service.rounds_done() {
                append_journal_round(&mut f, service.journal_round(k))?;
            }
            Some(f)
        }
        None => None,
    };

    // Scenario generators become ordinary ingest clients: one thread
    // each, distinct stream seeds, private shadow fleets. Anything else
    // holding an IngestHandle would feed the same queue identically.
    let handle = service.handle();
    let seed = service.config().seed;
    let threads: Vec<std::thread::JoinHandle<u64>> = (0..producers)
        .map(|i| {
            let mut producer = ScenarioProducer::new(
                service
                    .config()
                    .scenario
                    .clone()
                    .with_seed(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
                FleetState::new(
                    service.fleet().apps().to_vec(),
                    service.fleet().tiers().to_vec(),
                    service.fleet().assignment().clone(),
                ),
            );
            let h = handle.clone();
            std::thread::spawn(move || producer.run(&h, rounds))
        })
        .collect();

    loop {
        match service.ingest_round() {
            Some(rec) => {
                if let (Some(f), Some(dir)) = (journal_file.as_mut(), dir.as_ref()) {
                    append_journal_round(f, service.journal_round(rec.round))?;
                    if snapshot_every > 0 && service.rounds_done() % snapshot_every == 0 {
                        service.snapshot_traced().write(&dir.join("snapshot.json"))?;
                    }
                }
            }
            // An empty drain with every producer finished means the
            // queue is dry for good.
            None => {
                if threads.iter().all(|t| t.is_finished()) {
                    break;
                }
            }
        }
    }
    service.stop();
    let accepted: u64 = threads.into_iter().map(|t| t.join().unwrap_or(0)).sum();

    if let Some(dir) = dir.as_ref() {
        service.snapshot().write(&dir.join("snapshot.json"))?;
        println!("snapshot + journal in {}", dir.display());
    }
    println!("{}", service.metrics_json().pretty());
    warn_trace_io(service.obs_hub());
    let ingest = &service.metrics.ingest;
    println!(
        "ingest: {} round(s) ({} fast, {} full), {} event(s) queued by {} producer(s), {} shed, {} idle poll(s)",
        service.rounds_done(),
        ingest.fast_rounds,
        ingest.full_rounds,
        accepted,
        producers,
        ingest.shed.total(),
        ingest.idle_polls,
    );
    write_logs(
        p,
        &[
            ("log", service.rounds_json()),
            ("event-log", service.journal_json()),
        ],
    )
}

/// `serve --ingest --regions N` (N > 1): the multi-region ingest plane.
/// Producer threads route events into per-region bounded queues; each
/// region's pinned fabric worker drains its own queue under the shared
/// batch budget; the coordinator commits one region-tagged journal row
/// per round — so a killed process restores with `--restore` and every
/// region replays bit-identically.
fn cmd_serve_ingest_multi(p: &Parsed, config: ServiceConfig) -> Result<(), Error> {
    let producers = p.usize_at_least("producers", 1).map_err(usage)?;
    let dir = p.str("snapshot-dir").map_err(usage)?;
    let dir = (!dir.is_empty()).then(|| std::path::PathBuf::from(dir));
    let rounds = config.rounds;
    let snapshot_every = config.snapshot_every;
    // The hub exists before restore so a corrupt snapshot/journal fires
    // the flight trigger (dumping whatever the ring held) on the way out.
    let mut hub = build_obs_hub(p)?;

    let mut service = if p.flag("restore") {
        let Some(dir) = dir.as_ref() else {
            return Err(Error::Usage("--restore requires --snapshot-dir".into()));
        };
        let restored = (|| {
            let snap =
                MultiSnapshot::load(&dir.join("snapshot.json"))?.map_err(Error::SnapshotCorrupt)?;
            let journal =
                load_multi_journal(&dir.join("journal.jsonl"))?.map_err(Error::SnapshotCorrupt)?;
            let service = MultiRegionService::restore(config, &snap, &journal)?;
            Ok::<_, Error>((snap.rounds_done, service))
        })();
        match restored {
            Ok((snap_rounds, service)) => {
                println!(
                    "restored from snapshot at round {} (+{} journal tail round(s) replayed)",
                    snap_rounds,
                    service.rounds_done() - snap_rounds
                );
                service
            }
            Err(e) => {
                if let (Error::SnapshotCorrupt(_), Some(h)) = (&e, hub.as_mut()) {
                    h.trigger(FlightTrigger::SnapshotCorrupt, &e.to_string());
                }
                return Err(e);
            }
        }
    } else {
        MultiRegionService::new(config)
    };
    if let Some(hub) = hub.take() {
        service.attach_obs(hub);
    }

    // Same rewrite-don't-append contract as the single-region runtime:
    // the on-disk journal is regenerated from the verified in-memory
    // journal, so a torn tail line cannot corrupt the first new round.
    let mut journal_file = match dir.as_ref() {
        Some(dir) => {
            std::fs::create_dir_all(dir)?;
            let mut f = std::fs::File::create(dir.join("journal.jsonl"))?;
            for k in 0..service.rounds_done() {
                append_multi_journal_round(&mut f, &service.journal_round_all(k))?;
            }
            Some(f)
        }
        None => None,
    };

    // One scenario producer thread per (region, index) pair. Region r's
    // producers replay its per-region scenario stream (already
    // seed-split by region) further mixed per thread, mint events
    // against a private shadow of that region's fleet, and submit them
    // to region r's queue — the region-tagged half of the ingest plane.
    let handle = service.handle();
    let mut threads: Vec<std::thread::JoinHandle<u64>> = Vec::new();
    for r in 0..service.n_regions() {
        let scenario = service
            .config()
            .multi_scenario
            .as_ref()
            .map_or_else(|| service.config().scenario.clone(), |m| m.per_region[r].clone());
        let fleet = service.region_fleet(r);
        for i in 0..producers {
            let stream = scenario.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut producer = ScenarioProducer::new(
                scenario.clone().with_seed(stream),
                FleetState::new(
                    fleet.apps().to_vec(),
                    fleet.tiers().to_vec(),
                    fleet.assignment().clone(),
                ),
            );
            let h = handle.region(r).clone();
            threads.push(std::thread::spawn(move || producer.run(&h, rounds)));
        }
    }

    loop {
        match service.ingest_round() {
            Some(_) => {
                if let (Some(f), Some(dir)) = (journal_file.as_mut(), dir.as_ref()) {
                    let k = service.rounds_done() - 1;
                    append_multi_journal_round(f, &service.journal_round_all(k))?;
                    if snapshot_every > 0 && service.rounds_done() % snapshot_every == 0 {
                        service.snapshot_traced().write(&dir.join("snapshot.json"))?;
                    }
                }
            }
            // An empty drain across every region with every producer
            // finished means the queues are dry for good.
            None => {
                if threads.iter().all(|t| t.is_finished()) {
                    break;
                }
            }
        }
    }
    service.stop();
    let accepted: u64 = threads.into_iter().map(|t| t.join().unwrap_or(0)).sum();

    if let Some(dir) = dir.as_ref() {
        service.snapshot().write(&dir.join("snapshot.json"))?;
        println!("snapshot + journal in {}", dir.display());
    }
    println!("{}", service.metrics_json().pretty());
    warn_trace_io(service.obs_hub());
    let ingest = &service.metrics.ingest;
    println!(
        "ingest: {} region(s), {} round(s) ({} fast, {} full), {} event(s) queued by {} producer(s), {} shed, {} idle poll(s), {} migration(s)",
        service.n_regions(),
        service.rounds_done(),
        ingest.fast_rounds,
        ingest.full_rounds,
        accepted,
        producers * service.n_regions(),
        ingest.shed.total(),
        ingest.idle_polls,
        service.migrations().len(),
    );
    write_logs(
        p,
        &[
            ("log", service.rounds_json()),
            ("event-log", service.journal_json()),
        ],
    )
}

fn cmd_fig3(args: &[String]) -> Result<(), Error> {
    let cmd = Command::new("fig3", "regenerate Figure 3 (a/b/c)")
        .opt("scenario", "paper", "workload preset")
        .opt("seed", "42", "prng seed")
        .opt("timeout-ms", "100", "solver deadline (paper: 30s)")
        .opt("movement", "0.10", "movement fraction")
        .flag("csv", "print CSV instead of ASCII charts");
    with_parsed(cmd, args, |p| {
        let seed = p.u64("seed").map_err(usage)?;
        let bed = load_bed(&p.str("scenario").map_err(usage)?, seed)?;
        let rep = report::fig3_report(
            &bed,
            Duration::from_millis(p.u64("timeout-ms").map_err(usage)?),
            p.f64("movement").map_err(usage)?,
            seed,
        );
        if p.flag("csv") {
            print!("{}", rep.csv());
        } else {
            print!("{}", rep.ascii());
        }
        Ok(())
    })
}

fn cmd_sweep(args: &[String]) -> Result<(), Error> {
    let cmd = Command::new("sweep", "regenerate the Fig. 4/5 sweep")
        .opt("scenario", "paper", "workload preset")
        .opt("seed", "42", "prng seed")
        .opt("timeouts-ms", "50,100,300,900", "comma list of solver timeouts")
        .opt("movement", "0.10", "movement fraction");
    with_parsed(cmd, args, |p| {
        let seed = p.u64("seed").map_err(usage)?;
        let bed = load_bed(&p.str("scenario").map_err(usage)?, seed)?;
        let timeouts: Vec<Duration> = p
            .list("timeouts-ms")
            .map_err(usage)?
            .iter()
            .filter_map(|s| s.parse::<u64>().ok())
            .map(Duration::from_millis)
            .collect();
        let rows = report::sweep(&bed, &timeouts, p.f64("movement").map_err(usage)?, seed);
        println!("== Figure 4 rows ==");
        print!("{}", report::fig4_rows(&rows));
        println!("\n== Figure 5 rows ==");
        print!("{}", report::fig5_rows(&rows));
        Ok(())
    })
}

fn cmd_check(args: &[String]) -> Result<(), Error> {
    let cmd = Command::new("check", "verify AOT artifacts against the rust scorer")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("seed", "7", "prng seed");
    with_parsed(cmd, args, |p| {
        let dir = std::path::PathBuf::from(p.str("artifacts").map_err(usage)?);
        let mut scorer = sptlb::runtime::PjrtScorer::from_dir(&dir)
            .map_err(|e| Error::Solver(format!("artifact check FAILED: {e:#}")))?;
        let bed = generate(&WorkloadSpec::paper());
        let problem = sptlb::rebalancer::Problem::build(
            &bed.apps,
            &bed.tiers,
            bed.initial.clone(),
            sptlb::rebalancer::goals::MOVEMENT_FRACTION,
            Default::default(),
        )
        .unwrap();
        let mut rng = sptlb::util::prng::Pcg64::new(p.u64("seed").map_err(usage)?);
        let candidates: Vec<_> = (0..32)
            .map(|_| {
                let mut a = problem.initial.clone();
                let i = rng.range(0, problem.n_apps());
                let al = problem.apps[i].allowed;
                let t = al.nth(rng.range(0, al.len())).unwrap();
                a.set(sptlb::model::AppId::from_usize(i), t);
                a
            })
            .collect();
        let device = scorer
            .score(&problem, &candidates)
            .map_err(|e| Error::Solver(format!("artifact check FAILED: {e:#}")))?;
        let mut worst = 0.0f64;
        for (i, cand) in candidates.iter().enumerate() {
            let (cpu, _) = sptlb::rebalancer::score_assignment(&problem, cand);
            worst = worst.max((device[i] - cpu).abs() / cpu.abs().max(1.0));
        }
        if worst < 1e-3 {
            println!(
                "artifact check OK: 32 candidates, worst relative error {worst:.2e}, {} dispatch(es)",
                scorer.dispatches
            );
            Ok(())
        } else {
            Err(Error::Solver(format!(
                "parity FAILED: worst relative error {worst}"
            )))
        }
    })
}

/// `explain --trace t.jsonl --app 42 --round 17`: reconstruct the
/// propose → vet → avoid → escalate chain for one app around one round,
/// from the decision-provenance events in a `serve --trace` file
/// recorded at `--trace-level decisions`.
fn cmd_explain(args: &[String]) -> Result<(), Error> {
    let cmd = Command::new("explain", "reconstruct decision provenance from a trace")
        .opt("trace", "", "trace JSONL written by serve --trace (at level 'decisions')")
        .opt("app", "", "app id whose decisions to explain")
        .opt("round", "", "focus round")
        .opt("window", "8", "look-back window in rounds before --round");
    with_parsed(cmd, args, |p| {
        let path = p.str("trace").map_err(usage)?;
        if path.is_empty() {
            return Err(Error::Usage("explain requires --trace <file>".into()));
        }
        if p.get("app").map_or(true, |v| v.is_empty()) {
            return Err(Error::Usage("explain requires --app <id>".into()));
        }
        if p.get("round").map_or(true, |v| v.is_empty()) {
            return Err(Error::Usage("explain requires --round <n>".into()));
        }
        let query = obs::explain::ExplainQuery {
            app: p.u64("app").map_err(usage)? as u32,
            round: p.u64("round").map_err(usage)? as u32,
            window: p.u64("window").map_err(usage)? as u32,
        };
        let text = obs::explain::explain_trace(std::path::Path::new(&path), &query)?;
        print!("{text}");
        Ok(())
    })
}

fn cmd_bench(args: &[String]) -> Result<(), Error> {
    use sptlb::rebalancer::gap::{self, GapConfig};

    let cmd = Command::new("bench", "solution-quality harnesses (modes: gap)")
        .positionals(1)
        .opt("seed", "", "prng seed (default: harness default)")
        .opt("rounds", "", "scenario-evolution rounds per preset")
        .opt("movement", "", "movement fraction for the tiny instances")
        .opt("local-ms", "", "LocalSearch budget per cell in ms")
        .opt("exact-ms", "", "exhaustive/LP budget per cell in ms")
        .opt("out-dir", ".", "directory GAP_report.json is written to")
        .opt(
            "baseline",
            "",
            "gate this run against a baseline JSON (exit 1 on regression)",
        )
        .opt("tolerance", "0.05", "slack added to each baseline ceiling")
        .opt(
            "write-baseline",
            "",
            "derive a fresh baseline from this run and write it here",
        )
        .flag("smoke", "CI gate configuration (full grid, short budgets)");
    with_parsed(cmd, args, |p| {
        let mode = p.positionals.first().map(|s| s.as_str()).unwrap_or("gap");
        if mode != "gap" {
            return Err(Error::Usage(format!(
                "unknown bench mode '{mode}' (available: gap)"
            )));
        }
        let mut cfg = if p.flag("smoke") { GapConfig::smoke() } else { GapConfig::default() };
        // Empty-string defaults mean "keep the harness default" so the
        // smoke preset's budgets survive unless explicitly overridden.
        if p.get("seed").is_some_and(|v| !v.is_empty()) {
            cfg.seed = p.u64("seed").map_err(usage)?;
        }
        if p.get("rounds").is_some_and(|v| !v.is_empty()) {
            cfg.rounds = p.u64("rounds").map_err(usage)? as u32;
        }
        if p.get("movement").is_some_and(|v| !v.is_empty()) {
            cfg.movement_fraction = p.f64_in_range("movement", 0.0, 1.0).map_err(usage)?;
        }
        if p.get("local-ms").is_some_and(|v| !v.is_empty()) {
            cfg.local_ms = p.u64("local-ms").map_err(usage)?;
        }
        if p.get("exact-ms").is_some_and(|v| !v.is_empty()) {
            cfg.exact_ms = p.u64("exact-ms").map_err(usage)?;
        }

        let report = gap::run(&cfg);
        for cell in &report.cells {
            println!(
                "gap {:<8} {:<20} gap {:.4}  exact {:>9.4} ({} states{}) local {:>9.4}  lp {}",
                cell.preset,
                cell.mix,
                cell.gap,
                cell.exact_objective,
                cell.exact_states,
                if cell.exact_complete { "" } else { ", INCOMPLETE" },
                cell.local_objective,
                match cell.lp_objective {
                    Some(v) if cell.lp_certified =>
                        format!("{v:.4} certified in {} round(s)", cell.lp_tighten_rounds),
                    Some(v) => format!("{v:.4} uncertified"),
                    None => "infeasible/failed".to_string(),
                },
            );
        }
        println!(
            "max gap {:.4} over {} cell(s)",
            report.max_gap(),
            report.cells.len()
        );
        sptlb::bench::write_bench_json("GAP_report.json", &report.to_json());

        if let Some(path) = p.get("write-baseline").filter(|v| !v.is_empty()) {
            let baseline = gap::baseline_from(&report, 0.05);
            std::fs::write(path, baseline.pretty() + "\n")?;
            println!("baseline written to {path}");
        }

        if let Some(path) = p.get("baseline").filter(|v| !v.is_empty()) {
            let tolerance = p.f64("tolerance").map_err(usage)?;
            let text = std::fs::read_to_string(path)?;
            let baseline = Json::parse(&text)
                .map_err(|e| Error::Solver(format!("parsing baseline {path}: {e}")))?;
            let failures = gap::gate_against_baseline(&report, &baseline, tolerance);
            if failures.is_empty() {
                println!("gap gate OK against {path} (tolerance {tolerance})");
            } else {
                eprintln!("gap gate FAILED against {path}:");
                for f in &failures {
                    eprintln!("  - {f}");
                }
                return Err(Error::Solver(format!("gap gate failed against {path}")));
            }
        }
        Ok(())
    })
}
