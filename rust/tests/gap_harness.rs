//! Integration tests for the optimality-gap harness (`rebalancer::gap`).
//!
//! Property layer: on random tiny instances (≤ 8 apps, ≤ 3 tiers) the
//! three solver paths must agree — exhaustive enumeration is the ground
//! truth, LocalSearch can never beat it, and the LP relaxation's
//! feasibility verdict must match the integer search. Grid layer: the
//! full preset × mix run covers the shape the CI gap-gate consumes, and
//! the gate itself is demonstrated to pass at a derived baseline and
//! fail on an injected regression.

use sptlb::rebalancer::gap::{self, GapConfig};
use sptlb::rebalancer::lp::LpOutcome;
use sptlb::rebalancer::{exhaustive_search, score_assignment, LocalSearch, OptimalSearch};
use sptlb::util::json::Json;
use sptlb::util::propcheck::{forall, gen, Check};
use sptlb::util::timer::Deadline;
use sptlb::workload::{generate, WorkloadSpec};

#[derive(Debug)]
struct TinyCase {
    seed: u64,
    n_apps: usize,
    n_tiers: usize,
}

fn tiny_case(rng: &mut sptlb::util::prng::Pcg64) -> TinyCase {
    TinyCase {
        seed: rng.next_u64(),
        // generate() asserts n_apps >= n_tiers (4 > 3 keeps that true);
        // cap at 8 so 3^8 states stay enumerable inside the test budget.
        n_apps: gen::usize_in(rng, 4, 9),
        n_tiers: gen::usize_in(rng, 2, 4),
    }
}

/// Exhaustive search, the LP bound-tightening loop, and LocalSearch agree
/// on random tiny instances across every goal-weight mix:
/// - enumeration completes and its optimum lower-bounds LocalSearch;
/// - a capacity-feasible integer optimum implies a feasible LP (the
///   indicator point satisfies every relaxation row), so the LP may
///   report `Infeasible` only when no feasible integer assignment exists;
/// - when the LP is solvable, the tightening loop produces an incumbent.
#[test]
fn solvers_agree_on_random_tiny_instances() {
    let cfg = GapConfig { movement_fraction: 0.5, ..GapConfig::default() };
    forall(12, tiny_case, |case| {
        let mut spec = WorkloadSpec::small().with_seed(case.seed);
        spec.n_apps = case.n_apps;
        spec.n_tiers = case.n_tiers;
        let bed = generate(&spec);

        for mix in gap::MIXES {
            let problem =
                gap::build_problem(&cfg, &bed.apps, &bed.tiers, bed.initial.as_slice(), mix);

            let exact = exhaustive_search(&problem, Deadline::unbounded());
            if !exact.complete {
                return Check::fail(&format!(
                    "mix {mix}: exhaustive enumeration incomplete under an unbounded deadline"
                ));
            }
            let local =
                LocalSearch::with_seed(case.seed).solve(&problem, Deadline::after_ms(15));
            if exact.solution.score > local.score + 1e-9 {
                return Check::fail(&format!(
                    "mix {mix}: exhaustive optimum {} worse than LocalSearch {}",
                    exact.solution.score, local.score
                ));
            }
            if gap::relative_gap(exact.solution.score, local.score) < 0.0 {
                return Check::fail("relative gap went negative");
            }

            let lp = OptimalSearch::with_seed(case.seed).build_lp(&problem);
            let probe = lp.solve(50_000);
            let (_, breakdown) = score_assignment(&problem, &exact.solution.assignment);
            if breakdown.is_capacity_feasible() && probe == LpOutcome::Infeasible {
                return Check::fail(&format!(
                    "mix {mix}: integer optimum is capacity-feasible but the LP \
                     relaxation claims Infeasible"
                ));
            }
            if let LpOutcome::Optimal { objective, .. } = &probe {
                let tight = gap::tighten_lp(lp, 8, 50_000, Deadline::unbounded());
                match tight.objective {
                    None => {
                        return Check::fail(&format!(
                            "mix {mix}: LP solvable (objective {objective}) but the \
                             tightening loop produced no incumbent"
                        ))
                    }
                    Some(inc) => {
                        // The loop keeps the best incumbent, so it can
                        // only match or improve the one-shot solve.
                        if inc > objective + 1e-6 {
                            return Check::fail(&format!(
                                "mix {mix}: tightened incumbent {inc} worse than \
                                 one-shot LP optimum {objective}"
                            ));
                        }
                    }
                }
            }
        }
        Check::pass()
    });
}

/// `floor(6 × MOVEMENT_FRACTION) = 0` moves: with no movement budget all
/// solvers are pinned to the incumbent assignment, so the gap is exactly
/// zero. This pins the harness's behaviour at the budget edge instead of
/// letting a zero-move cell masquerade as "LocalSearch matched optimal".
#[test]
fn zero_move_budget_pins_every_solver_to_the_incumbent() {
    let mut spec = WorkloadSpec::small().with_seed(11);
    spec.n_apps = 6;
    spec.n_tiers = 3;
    let bed = generate(&spec);
    let cfg = GapConfig {
        movement_fraction: sptlb::rebalancer::goals::MOVEMENT_FRACTION,
        ..GapConfig::default()
    };
    let problem =
        gap::build_problem(&cfg, &bed.apps, &bed.tiers, bed.initial.as_slice(), "balanced");
    assert_eq!(problem.max_moves, 0);

    let exact = exhaustive_search(&problem, Deadline::unbounded());
    assert!(exact.complete);
    assert_eq!(exact.solution.assignment.as_slice(), problem.initial.as_slice());

    let local = LocalSearch::with_seed(11).solve(&problem, Deadline::after_ms(10));
    assert_eq!(local.assignment.as_slice(), problem.initial.as_slice());
    assert_eq!(gap::relative_gap(exact.solution.score, local.score), 0.0);
}

/// The full preset × mix grid: every cell present exactly once, exact
/// enumeration complete everywhere, and the committed CI baseline covers
/// the whole grid so the gate can never fail on a missing key.
#[test]
fn full_grid_covers_every_preset_mix_cell() {
    let mut cfg = GapConfig::smoke();
    // Tests share CI cores with the rest of the suite: keep the local
    // budget minimal and give enumeration slack so `exact_complete`
    // cannot flake under load.
    cfg.local_ms = 10;
    cfg.exact_ms = 5_000;
    let report = gap::run(&cfg);

    assert_eq!(report.cells.len(), 24, "6 presets × 4 mixes");
    let keys: std::collections::BTreeSet<String> =
        report.cells.iter().map(|c| c.key()).collect();
    assert_eq!(keys.len(), 24, "cell keys must be unique");
    let json = report.to_json();
    assert_eq!(json.get("n_presets").as_f64(), Some(6.0));
    assert_eq!(json.get("n_mixes").as_f64(), Some(4.0));
    for cell in &report.cells {
        assert!(cell.exact_complete, "cell {} did not finish enumeration", cell.key());
        assert!(cell.gap >= 0.0, "cell {} has a negative gap", cell.key());
        assert!(cell.n_apps <= cfg.max_apps, "cell {} outgrew the arrival cap", cell.key());
    }

    let committed = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/gap_baseline.json"
    ))
    .expect("committed baseline rust/gap_baseline.json must exist");
    let baseline = Json::parse(&committed).expect("committed baseline must parse");
    assert_eq!(baseline.get("kind").as_str(), Some("gap_baseline"));
    for cell in &report.cells {
        assert!(
            baseline.get("cells").get(&cell.key()).as_f64().is_some(),
            "committed baseline is missing cell {}",
            cell.key()
        );
    }
}

/// End-to-end gate demonstration on a measured report: a baseline derived
/// from the run passes, and injecting a regression into one cell makes
/// the gate fail with a message naming exactly that cell.
#[test]
fn gate_passes_at_derived_baseline_and_fails_on_injected_regression() {
    let mut cfg = GapConfig::smoke();
    cfg.presets = vec!["steady".to_string(), "churn".to_string()];
    cfg.local_ms = 10;
    cfg.exact_ms = 5_000;
    let report = gap::run(&cfg);
    assert_eq!(report.cells.len(), 8);

    let baseline = gap::baseline_from(&report, 0.05);
    assert!(
        gap::gate_against_baseline(&report, &baseline, 0.01).is_empty(),
        "a report must pass the baseline derived from itself"
    );

    let mut regressed = report.clone();
    regressed.cells[3].gap = 10.0;
    let failures = gap::gate_against_baseline(&regressed, &baseline, 0.01);
    assert_eq!(failures.len(), 1);
    assert!(
        failures[0].contains(&report.cells[3].key()),
        "failure message must name the regressed cell: {}",
        failures[0]
    );
}
