//! Assignments: the app→tier mapping SPTLB produces, plus move diffs and
//! projected tier metrics derived from them.

use crate::model::app::{App, AppId};
use crate::model::resources::ResourceVec;
use crate::model::tier::{Tier, TierId};
use crate::util::json::Json;

/// A complete app→tier mapping, indexed by dense `AppId`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Assignment {
    tier_of: Vec<TierId>,
}

impl Assignment {
    pub fn new(tier_of: Vec<TierId>) -> Self {
        Self { tier_of }
    }

    /// Consume into the raw position→tier column — zero-copy handoff
    /// into solver state (the inverse of [`Assignment::new`]).
    pub fn into_vec(self) -> Vec<TierId> {
        self.tier_of
    }

    pub fn uniform(n_apps: usize, tier: TierId) -> Self {
        Self { tier_of: vec![tier; n_apps] }
    }

    pub fn n_apps(&self) -> usize {
        self.tier_of.len()
    }

    pub fn tier_of(&self, app: AppId) -> TierId {
        self.tier_of[app.idx()]
    }

    pub fn set(&mut self, app: AppId, tier: TierId) {
        self.tier_of[app.idx()] = tier;
    }

    /// Grow the mapping by one app placed on `tier` (fleet arrival; the
    /// new app occupies the last position).
    pub fn push(&mut self, tier: TierId) {
        self.tier_of.push(tier);
    }

    /// Remove the app at `index`, shifting later positions down (fleet
    /// departure — positions stay parallel to the id-ordered app list).
    pub fn remove(&mut self, index: usize) -> TierId {
        self.tier_of.remove(index)
    }

    pub fn iter(&self) -> impl Iterator<Item = (AppId, TierId)> + '_ {
        self.tier_of.iter().enumerate().map(|(a, t)| (AppId::from_usize(a), *t))
    }

    pub fn as_slice(&self) -> &[TierId] {
        &self.tier_of
    }

    /// Overwrite this mapping with `other`'s, reusing the existing
    /// buffer: a same-size copy never touches the allocator, which the
    /// incremental engine's steady-state rounds depend on.
    pub fn copy_from(&mut self, other: &Assignment) {
        self.tier_of.clone_from(&other.tier_of);
    }

    /// Apps moved relative to `from` (the diff §3.3 reports).
    pub fn moves_from(&self, from: &Assignment) -> Vec<Move> {
        assert_eq!(self.n_apps(), from.n_apps(), "assignment size mismatch");
        self.iter()
            .filter(|(a, t)| from.tier_of(*a) != *t)
            .map(|(a, t)| Move { app: a, from: from.tier_of(a), to: t })
            .collect()
    }

    pub fn move_count_from(&self, from: &Assignment) -> usize {
        self.iter().filter(|(a, t)| from.tier_of(*a) != *t).count()
    }

    /// Projected absolute tier loads for a given app population. `apps`
    /// is positional-parallel to the mapping (apps in ascending-id order;
    /// ids themselves may be sparse once departures exist).
    pub fn tier_loads(&self, apps: &[App], n_tiers: usize) -> Vec<ResourceVec> {
        assert_eq!(apps.len(), self.n_apps(), "assignment size mismatch");
        let mut loads = vec![ResourceVec::ZERO; n_tiers];
        for (t, app) in self.tier_of.iter().zip(apps) {
            loads[t.idx()] += app.demand;
        }
        loads
    }

    /// Projected per-tier utilization fractions.
    pub fn tier_utilizations(&self, apps: &[App], tiers: &[Tier]) -> Vec<ResourceVec> {
        self.tier_loads(apps, tiers.len())
            .iter()
            .zip(tiers)
            .map(|(load, tier)| tier.utilization_of(load))
            .collect()
    }

    /// Apps hosted per tier.
    pub fn apps_per_tier(&self, n_tiers: usize) -> Vec<usize> {
        let mut counts = vec![0usize; n_tiers];
        for t in &self.tier_of {
            counts[t.idx()] += 1;
        }
        counts
    }

    pub fn to_json(&self) -> Json {
        Json::arr(self.tier_of.iter().map(|t| Json::num(t.0 as f64)))
    }

    pub fn from_json(j: &Json) -> Option<Assignment> {
        let arr = j.as_arr()?;
        let tier_of = arr
            .iter()
            .map(|v| v.as_usize().map(TierId::from_usize))
            .collect::<Option<Vec<_>>>()?;
        Some(Assignment::new(tier_of))
    }
}

/// One app movement (§3.3's recommendation unit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Move {
    pub app: AppId,
    pub from: TierId,
    pub to: TierId,
}

impl Move {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("app", Json::num(self.app.0 as f64)),
            ("from", Json::num(self.from.0 as f64)),
            ("to", Json::num(self.to.0 as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::app::{Criticality, Slo};
    use crate::model::region::{RegionId, RegionSet};
    use crate::model::tier::default_ideal_utilization;

    fn mk_apps() -> Vec<App> {
        (0..4)
            .map(|i| App {
                id: AppId::from_usize(i),
                name: format!("app{i}"),
                demand: ResourceVec::new(1.0 + i as f64, 2.0, 10.0),
                slo: Slo::Slo3,
                criticality: Criticality::new(0.5),
                preferred_region: RegionId(0),
            })
            .collect()
    }

    fn mk_tiers(n: usize) -> Vec<Tier> {
        (0..n)
            .map(|i| Tier {
                id: TierId::from_usize(i),
                name: format!("tier{}", i + 1),
                capacity: ResourceVec::new(100.0, 100.0, 100.0),
                ideal_utilization: default_ideal_utilization(),
                supported_slos: vec![Slo::Slo3],
                regions: RegionSet::from_indices([0]),
            })
            .collect()
    }

    #[test]
    fn loads_sum_demands_per_tier() {
        let apps = mk_apps();
        let asg = Assignment::new(vec![TierId(0), TierId(0), TierId(1), TierId(1)]);
        let loads = asg.tier_loads(&apps, 2);
        assert_eq!(loads[0], ResourceVec::new(3.0, 4.0, 20.0)); // apps 0,1
        assert_eq!(loads[1], ResourceVec::new(7.0, 4.0, 20.0)); // apps 2,3
    }

    #[test]
    fn moves_diff() {
        let a = Assignment::new(vec![TierId(0), TierId(1), TierId(0)]);
        let b = Assignment::new(vec![TierId(0), TierId(0), TierId(1)]);
        let moves = b.moves_from(&a);
        assert_eq!(moves.len(), 2);
        assert!(moves.contains(&Move { app: AppId(1), from: TierId(1), to: TierId(0) }));
        assert!(moves.contains(&Move { app: AppId(2), from: TierId(0), to: TierId(1) }));
        assert_eq!(b.move_count_from(&a), 2);
        assert_eq!(a.move_count_from(&a), 0);
    }

    #[test]
    fn utilizations_divide_by_capacity() {
        let apps = mk_apps();
        let tiers = mk_tiers(2);
        let asg = Assignment::uniform(4, TierId(0));
        let utils = asg.tier_utilizations(&apps, &tiers);
        assert!((utils[0].cpu() - 0.10).abs() < 1e-12); // (1+2+3+4)/100
        assert_eq!(utils[1], ResourceVec::ZERO);
    }

    #[test]
    fn apps_per_tier_counts() {
        let asg = Assignment::new(vec![TierId(2), TierId(0), TierId(2)]);
        assert_eq!(asg.apps_per_tier(3), vec![1, 0, 2]);
    }

    #[test]
    fn json_roundtrip() {
        let asg = Assignment::new(vec![TierId(1), TierId(4), TierId(0)]);
        let j = asg.to_json().to_string();
        assert_eq!(Assignment::from_json(&Json::parse(&j).unwrap()).unwrap(), asg);
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn moves_from_size_mismatch_panics() {
        let a = Assignment::uniform(2, TierId(0));
        let b = Assignment::uniform(3, TierId(0));
        let _ = b.moves_from(&a);
    }
}
