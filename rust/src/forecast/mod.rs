//! Load forecasting — the proactive half of the coordinator (ROADMAP:
//! "robust and *proactive* to application load"). Every scheduler layer
//! used to react to the *last scraped* load sample; this subsystem gives
//! them a forward view instead:
//!
//! ```text
//!   history ring buffers  (per app, registered peak demand — appended
//!        │                 only when an event touched the app)
//!        ▼
//!   Forecaster            (pure function of the ring buffer: naive-last,
//!        │                 ewma, holt, seasonal-naive)
//!        ▼
//!   predicted demand      → ScoreState's predicted-headroom goal
//!                         → GlobalScheduler's predicted region pressure
//! ```
//!
//! # Determinism contract
//!
//! A forecast is a **pure function** of (forecaster, history, horizon,
//! period). Histories are driven exclusively by the fleet event stream —
//! identical for any worker count, region count, and for both engine
//! modes — so forecasts are bit-identical everywhere the decisions must
//! be (`rust/tests/forecast.rs` pins this). No PRNG, no clock, no
//! thread-order dependence anywhere in this module.
//!
//! # Totality contract
//!
//! Every forecaster returns finite, non-negative predictions for *any*
//! (possibly empty, possibly degenerate) history — enforced by a
//! propcheck below and re-pinned end-to-end in `rust/tests/forecast.rs`.
//! A non-finite intermediate falls back to the last observation, and an
//! empty history forecasts zero.

use crate::model::{AppId, ResourceVec, NUM_RESOURCES};

/// EWMA smoothing factor (weight of the newest observation).
const EWMA_ALPHA: f64 = 0.4;
/// Holt level smoothing factor.
const HOLT_ALPHA: f64 = 0.5;
/// Holt trend smoothing factor.
const HOLT_BETA: f64 = 0.3;

/// Which per-app load forecaster the coordinator runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ForecasterKind {
    /// Forecasting off: the scheduler stays purely reactive (no
    /// predicted-headroom goal, no predicted region pressure).
    None,
    /// Next value = last observation.
    NaiveLast,
    /// Exponentially weighted moving average (level only).
    Ewma,
    /// Holt's linear method (level + trend): extrapolates rises and
    /// falls, so rising tiers are evacuated *before* they peak.
    Holt,
    /// Value one season ago: exact on periodic (diurnal) workloads once
    /// a full period of history exists; falls back to naive-last before.
    SeasonalNaive,
}

impl ForecasterKind {
    pub const ALL: [ForecasterKind; 5] = [
        ForecasterKind::None,
        ForecasterKind::NaiveLast,
        ForecasterKind::Ewma,
        ForecasterKind::Holt,
        ForecasterKind::SeasonalNaive,
    ];

    /// CLI-facing names, in [`ForecasterKind::ALL`] order.
    pub const NAMES: [&'static str; 5] = ["none", "naive-last", "ewma", "holt", "seasonal-naive"];

    pub fn name(self) -> &'static str {
        match self {
            ForecasterKind::None => "none",
            ForecasterKind::NaiveLast => "naive-last",
            ForecasterKind::Ewma => "ewma",
            ForecasterKind::Holt => "holt",
            ForecasterKind::SeasonalNaive => "seasonal-naive",
        }
    }

    pub fn from_name(s: &str) -> Option<ForecasterKind> {
        match s {
            "none" => Some(ForecasterKind::None),
            "naive-last" | "naive" | "last" => Some(ForecasterKind::NaiveLast),
            "ewma" => Some(ForecasterKind::Ewma),
            "holt" => Some(ForecasterKind::Holt),
            "seasonal-naive" | "seasonal" => Some(ForecasterKind::SeasonalNaive),
            _ => None,
        }
    }

    /// Does this kind feed predictions into the schedulers at all?
    pub fn is_enabled(self) -> bool {
        self != ForecasterKind::None
    }

    /// Forecast the demand `horizon` observations ahead of `series`
    /// (oldest first). Pure; per-resource; total (see module docs).
    pub fn forecast(self, series: &[ResourceVec], horizon: u32, period: u32) -> ResourceVec {
        let mut out = ResourceVec::ZERO;
        for k in 0..NUM_RESOURCES {
            let xs: Vec<f64> = series.iter().map(|d| d.0[k]).collect();
            out.0[k] = sanitize(self.forecast_scalar(&xs, horizon, period), &xs);
        }
        out
    }

    fn forecast_scalar(self, xs: &[f64], horizon: u32, period: u32) -> f64 {
        let Some(&last) = xs.last() else { return 0.0 };
        let horizon = horizon.max(1);
        match self {
            // `None` never reaches the schedulers, but stays total so the
            // propcheck can sweep ALL kinds uniformly.
            ForecasterKind::None | ForecasterKind::NaiveLast => last,
            ForecasterKind::Ewma => {
                let mut level = xs[0];
                for &x in &xs[1..] {
                    level = EWMA_ALPHA * x + (1.0 - EWMA_ALPHA) * level;
                }
                level
            }
            ForecasterKind::Holt => {
                if xs.len() < 2 {
                    return last;
                }
                let mut level = xs[0];
                let mut trend = xs[1] - xs[0];
                for &x in &xs[1..] {
                    let prev = level;
                    level = HOLT_ALPHA * x + (1.0 - HOLT_ALPHA) * (level + trend);
                    trend = HOLT_BETA * (level - prev) + (1.0 - HOLT_BETA) * trend;
                }
                level + horizon as f64 * trend
            }
            ForecasterKind::SeasonalNaive => {
                let p = period.max(1) as usize;
                if xs.len() < p {
                    return last;
                }
                xs[xs.len() - p + ((horizon as usize - 1) % p)]
            }
        }
    }
}

/// Clamp a raw scalar forecast to the totality contract: finite and
/// non-negative, falling back to the last observation (then zero).
fn sanitize(v: f64, xs: &[f64]) -> f64 {
    if v.is_finite() {
        return v.max(0.0);
    }
    match xs.last() {
        Some(&l) if l.is_finite() => l.max(0.0),
        _ => 0.0,
    }
}

/// Forecast-subsystem knobs (CLI: `serve --forecaster/--horizon/--history`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ForecastConfig {
    pub forecaster: ForecasterKind,
    /// Observations ahead to forecast for the predicted-headroom goal.
    pub horizon: u32,
    /// Ring-buffer capacity per app (observations kept).
    pub history: usize,
    /// Season length for `seasonal-naive` (observations per cycle; the
    /// `diurnal` scenario's default wave period).
    pub period: u32,
}

impl Default for ForecastConfig {
    fn default() -> Self {
        Self { forecaster: ForecasterKind::None, horizon: 3, history: 32, period: 12 }
    }
}

impl ForecastConfig {
    pub fn is_enabled(&self) -> bool {
        self.forecaster.is_enabled()
    }
}

/// Sentinel for "this app id has no slot".
const NO_SLOT: u32 = u32::MAX;

/// Per-app demand-history ring buffers, slot-indexed by fleet-stable id.
/// An entry is appended only when an event *touched* the app (arrival,
/// drift) — the incremental capture the engine relies on — so a steady
/// app holds one observation and costs nothing per round.
///
/// # Layout
///
/// The hot dirty-set path (`observe`/`series` every round) does **no
/// tree lookup and no per-append reallocation**: app ids are monotonic
/// small integers, so `index[id]` maps straight to a slot (a dense id →
/// slot table, `u32::MAX` = none), and each slot's buffer is
/// preallocated to `2·cap` on first use. A slot grows to at most
/// `2·cap − 1` entries before one bulk wrap-around drain, and
/// [`HistoryStore::series`] only ever exposes the last `cap` — window
/// semantics are identical to a per-push shift without its O(cap) cost
/// on every observation (bit-identical to the old `BTreeMap<AppId,
/// Vec<_>>` store; pinned below). Departed apps' slots are recycled
/// through a free list, so long-churn runs don't leak buffers.
#[derive(Debug, Clone)]
pub struct HistoryStore {
    cap: usize,
    /// App id → slot (`NO_SLOT` = none). Grows to the max id ever seen.
    index: Vec<u32>,
    /// Slot-indexed ring buffers; a freed slot keeps its allocation.
    slots: Vec<Vec<ResourceVec>>,
    /// Recycled slots awaiting reuse.
    free: Vec<u32>,
}

impl HistoryStore {
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(2), index: Vec::new(), slots: Vec::new(), free: Vec::new() }
    }

    fn slot_of(&self, id: AppId) -> Option<usize> {
        match self.index.get(id.idx()) {
            Some(&s) if s != NO_SLOT => Some(s as usize),
            _ => None,
        }
    }

    /// Append an observation for `id` — O(1), allocation-free once the
    /// app's slot exists (amortized O(1) across the bulk drain).
    pub fn observe(&mut self, id: AppId, demand: ResourceVec) {
        let cap = self.cap;
        let slot = match self.slot_of(id) {
            Some(s) => s,
            None => {
                if self.index.len() <= id.idx() {
                    self.index.resize(id.idx() + 1, NO_SLOT);
                }
                let s = match self.free.pop() {
                    Some(s) => s as usize,
                    None => {
                        self.slots.push(Vec::with_capacity(2 * cap));
                        self.slots.len() - 1
                    }
                };
                self.index[id.idx()] = s as u32;
                s
            }
        };
        let buf = &mut self.slots[slot];
        buf.push(demand);
        if buf.len() >= 2 * cap {
            buf.drain(..buf.len() - cap);
        }
    }

    /// Drop a departed app's series; the slot (and its allocation) is
    /// recycled for the next arrival.
    pub fn remove(&mut self, id: AppId) {
        if let Some(s) = self.slot_of(id) {
            self.index[id.idx()] = NO_SLOT;
            self.slots[s].clear();
            self.free.push(s as u32);
        }
    }

    /// The last `cap` observations recorded for `id`, oldest first
    /// (empty if never observed).
    pub fn series(&self, id: AppId) -> &[ResourceVec] {
        match self.slot_of(id) {
            Some(s) => {
                let buf = &self.slots[s];
                &buf[buf.len().saturating_sub(self.cap)..]
            }
            None => &[],
        }
    }

    /// Apps with at least one observation.
    pub fn n_apps(&self) -> usize {
        self.slots.len() - self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{forall, Check};

    fn constant(v: f64, n: usize) -> Vec<ResourceVec> {
        vec![ResourceVec::splat(v); n]
    }

    #[test]
    fn names_roundtrip() {
        for k in ForecasterKind::ALL {
            assert_eq!(ForecasterKind::from_name(k.name()), Some(k));
        }
        assert_eq!(ForecasterKind::from_name("seasonal"), Some(ForecasterKind::SeasonalNaive));
        assert!(ForecasterKind::from_name("oracle").is_none());
        assert_eq!(ForecasterKind::ALL.len(), ForecasterKind::NAMES.len());
        for (k, n) in ForecasterKind::ALL.iter().zip(ForecasterKind::NAMES) {
            assert_eq!(k.name(), n);
        }
    }

    #[test]
    fn all_forecasters_are_exact_on_constant_series() {
        let series = constant(5.0, 20);
        for k in ForecasterKind::ALL {
            let f = k.forecast(&series, 3, 6);
            for r in 0..NUM_RESOURCES {
                assert!((f.0[r] - 5.0).abs() < 1e-9, "{} on constant", k.name());
            }
        }
    }

    #[test]
    fn holt_extrapolates_a_linear_trend() {
        // 1, 2, ..., 10 — Holt must predict ~10 + h on a clean ramp.
        let series: Vec<ResourceVec> =
            (1..=10).map(|i| ResourceVec::splat(i as f64)).collect();
        let f = ForecasterKind::Holt.forecast(&series, 3, 12);
        assert!((f.cpu() - 13.0).abs() < 1.0, "holt 3-ahead on ramp: {}", f.cpu());
        let naive = ForecasterKind::NaiveLast.forecast(&series, 3, 12);
        assert!(f.cpu() > naive.cpu(), "holt must see the rise coming");
    }

    #[test]
    fn seasonal_naive_repeats_the_last_season() {
        // Period-4 sawtooth: 1 2 3 4 | 1 2 3 4 — h-ahead must pick the
        // matching point of the last season.
        let series: Vec<ResourceVec> = (0..8)
            .map(|i| ResourceVec::splat((i % 4 + 1) as f64))
            .collect();
        for h in 1..=8u32 {
            let f = ForecasterKind::SeasonalNaive.forecast(&series, h, 4);
            let expect = ((h as usize - 1) % 4 + 1) as f64;
            assert_eq!(f.cpu(), expect, "h={h}");
        }
        // Shorter than a season: fall back to the last observation.
        let short = constant(7.0, 2);
        assert_eq!(ForecasterKind::SeasonalNaive.forecast(&short, 1, 4).cpu(), 7.0);
    }

    #[test]
    fn empty_history_forecasts_zero() {
        for k in ForecasterKind::ALL {
            assert_eq!(k.forecast(&[], 1, 4), ResourceVec::ZERO, "{}", k.name());
        }
    }

    #[test]
    fn forecasts_are_total_on_arbitrary_histories() {
        // The module's totality contract: finite, non-negative outputs
        // for any history length/values, any horizon, any period —
        // including zeros, spikes, and length-degenerate inputs.
        forall(
            200,
            |rng| {
                let len = rng.range(0, 40);
                let series: Vec<ResourceVec> = (0..len)
                    .map(|_| {
                        let spike = if rng.chance(0.1) { 1e6 } else { 1.0 };
                        ResourceVec::new(
                            rng.uniform(0.0, 50.0) * spike,
                            rng.uniform(0.0, 200.0),
                            rng.uniform(0.0, 500.0).round(),
                        )
                    })
                    .collect();
                let horizon = rng.range(0, 9) as u32;
                let period = rng.range(0, 16) as u32;
                (series, horizon, period)
            },
            |(series, horizon, period)| {
                for k in ForecasterKind::ALL {
                    let f = k.forecast(series, *horizon, *period);
                    for r in 0..NUM_RESOURCES {
                        if !f.0[r].is_finite() || f.0[r] < 0.0 {
                            return Check::fail(&format!(
                                "{} produced {} (len={}, h={horizon}, p={period})",
                                k.name(),
                                f.0[r],
                                series.len()
                            ));
                        }
                    }
                }
                Check::pass()
            },
        );
    }

    #[test]
    fn history_ring_evicts_oldest_at_capacity() {
        let mut h = HistoryStore::new(3);
        // The exposed window is always the last `cap` observations, on
        // both sides of the amortized bulk-drain boundary (2·cap).
        for i in 0..12 {
            h.observe(AppId(1), ResourceVec::splat(i as f64));
            let s = h.series(AppId(1));
            assert_eq!(s.len(), (i + 1).min(3), "after observation {i}");
            assert_eq!(s[s.len() - 1].cpu(), i as f64);
            assert_eq!(s[0].cpu(), (i as i64 - 2).max(0) as f64);
        }
        assert!(h.series(AppId(2)).is_empty());
        h.remove(AppId(1));
        assert_eq!(h.n_apps(), 0);
    }

    #[test]
    fn slot_store_is_bit_identical_to_the_legacy_tree_store() {
        // The slot-indexed store must reproduce the old
        // `BTreeMap<AppId, Vec<ResourceVec>>` store exactly — same
        // windows, same forecasts to the bit — across arbitrary
        // observe/remove churn (including id reuse of freed slots by
        // later arrivals and re-observation after removal).
        use std::collections::BTreeMap;

        struct LegacyStore {
            cap: usize,
            series: BTreeMap<AppId, Vec<ResourceVec>>,
        }
        impl LegacyStore {
            fn observe(&mut self, id: AppId, demand: ResourceVec) {
                let cap = self.cap;
                let s = self.series.entry(id).or_default();
                s.push(demand);
                if s.len() >= 2 * cap {
                    s.drain(..s.len() - cap);
                }
            }
            fn series(&self, id: AppId) -> &[ResourceVec] {
                match self.series.get(&id) {
                    Some(v) => &v[v.len().saturating_sub(self.cap)..],
                    None => &[],
                }
            }
        }

        forall(
            30,
            |rng| {
                let cap = rng.range(2, 8);
                let ops: Vec<(bool, usize, f64)> = (0..rng.range(10, 120))
                    .map(|_| (rng.chance(0.15), rng.range(0, 12), rng.uniform(0.0, 50.0)))
                    .collect();
                (cap, ops)
            },
            |(cap, ops)| {
                let cap = *cap;
                let mut new = HistoryStore::new(cap);
                let mut old = LegacyStore { cap: cap.max(2), series: BTreeMap::new() };
                for (remove, id, v) in ops {
                    let id = AppId::from_usize(*id);
                    if *remove {
                        new.remove(id);
                        old.series.remove(&id);
                    } else {
                        let d = ResourceVec::splat(*v);
                        new.observe(id, d);
                        old.observe(id, d);
                    }
                }
                for raw in 0..12 {
                    let id = AppId(raw);
                    if new.series(id) != old.series(id) {
                        return Check::fail(&format!("series diverged for app {raw}"));
                    }
                    for k in ForecasterKind::ALL {
                        let a = k.forecast(new.series(id), 3, 4);
                        let b = k.forecast(old.series(id), 3, 4);
                        for r in 0..NUM_RESOURCES {
                            if a.0[r].to_bits() != b.0[r].to_bits() {
                                return Check::fail(&format!(
                                    "{} forecast diverged for app {raw}",
                                    k.name()
                                ));
                            }
                        }
                    }
                }
                if new.n_apps() != old.series.len() {
                    return Check::fail("n_apps diverged");
                }
                Check::pass()
            },
        );
    }

    #[test]
    fn slot_store_recycles_freed_slots() {
        let mut h = HistoryStore::new(3);
        for i in 0..4 {
            h.observe(AppId(i), ResourceVec::splat(i as f64));
        }
        assert_eq!(h.n_apps(), 4);
        h.remove(AppId(1));
        h.remove(AppId(2));
        assert_eq!(h.n_apps(), 2);
        assert!(h.series(AppId(1)).is_empty());
        // New arrivals reuse the freed slots; old series never bleed in.
        h.observe(AppId(10), ResourceVec::splat(99.0));
        h.observe(AppId(11), ResourceVec::splat(98.0));
        assert_eq!(h.n_apps(), 4);
        assert_eq!(h.series(AppId(10)), &[ResourceVec::splat(99.0)]);
        assert_eq!(h.series(AppId(11)), &[ResourceVec::splat(98.0)]);
        // A removed id can be re-observed from scratch.
        h.observe(AppId(1), ResourceVec::splat(1.5));
        assert_eq!(h.series(AppId(1)), &[ResourceVec::splat(1.5)]);
    }

    #[test]
    fn forecast_is_a_pure_function_of_the_series() {
        let series: Vec<ResourceVec> =
            (0..16).map(|i| ResourceVec::splat((i * i % 7) as f64)).collect();
        for k in ForecasterKind::ALL {
            let a = k.forecast(&series, 4, 8);
            let b = k.forecast(&series, 4, 8);
            assert_eq!(a, b, "{} must be deterministic", k.name());
        }
    }
}
