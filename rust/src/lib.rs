//! # SPTLB — Stream-Processing Tier Load Balancer
//!
//! Reproduction of "Designing Co-operation in Systems of Hierarchical,
//! Multi-objective Schedulers for Stream Processing" (Meta, CS.DC 2025).
//! See DESIGN.md for the system inventory and experiment index.

pub mod greedy;
pub mod hierarchy;
pub mod bench;
pub mod coop;
pub mod coordinator;
pub mod forecast;
pub mod metadata;
pub mod metrics;
pub mod model;
pub mod network;
pub mod obs;
pub mod rebalancer;
pub mod report;
pub mod runtime;
pub mod service;
pub mod sptlb;
pub mod util;
pub mod workload;

/// The one-stop import for embedding the balancer as a service:
///
/// ```
/// use sptlb::prelude::*;
///
/// let config = ServiceConfig::builder().workload("small").build().unwrap();
/// let service = Service::new(config);
/// assert_eq!(service.rounds_done(), 0);
/// ```
pub mod prelude {
    pub use crate::coordinator::ServiceMetrics;
    pub use crate::model::FleetEvent;
    pub use crate::service::{
        Backpressure, ConfigError, Error, IngestHandle, MultiIngestHandle, MultiRegionService,
        MultiSnapshot, Service, ServiceConfig, ServiceRound, Snapshot,
    };
}
