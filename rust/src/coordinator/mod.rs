//! Coordinator (DESIGN.md S12): the long-running leader loop that turns
//! SPTLB from a one-shot solver into a service. Each *round* it draws the
//! round's [`FleetEvent`]s from the configured scenario, applies them to
//! the owned [`FleetState`], and hands the dirty-set to the round engine
//! (collect → construct → solve → execute); accepted moves are adopted
//! into the incumbent in place, the decision log grows, and service
//! metrics accumulate. Backpressure: if a round overruns the tick budget,
//! subsequent ticks are skipped rather than queued (the paper's
//! schedulers run on fresh data, never on a backlog).
//!
//! The default [`EngineMode::Incremental`] engine reacts to event deltas;
//! [`EngineMode::Rebuild`] recomputes everything per round and must
//! produce bit-identical reports (see `coordinator::engine` module docs).

pub mod engine;
pub mod fleet;
pub mod multiregion;

pub use engine::{EngineMode, FleetEngine};
pub use fleet::{FleetDelta, FleetState};
pub use multiregion::{
    parse_multiregion_event_log, MigrationRecord, MultiRegionConfig, MultiRegionCoordinator,
    MultiRegionMetrics, MultiRegionRound, RegionExecution,
};

use crate::coop::RejectCounts;
use crate::forecast::ForecastConfig;
use crate::metrics::IngestStats;
use crate::model::{App, Assignment, FleetEvent, ResourceVec, Tier};
use crate::network::LatencyMatrix;
use crate::obs::{self, FlightTrigger, ObsHub, SpanRecorder};
use crate::sptlb::{BalanceReport, SptlbConfig};
use crate::util::json::Json;
use crate::util::stats::OnlineStats;
use crate::util::timer::Stopwatch;
use crate::workload::{ScenarioConfig, ScenarioGen};
use std::time::Duration;

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub sptlb: SptlbConfig,
    /// Tick budget per round; rounds that overrun skip following ticks.
    pub tick: Duration,
    /// Event-stream scenario driving the fleet between rounds.
    pub scenario: ScenarioConfig,
    /// Round engine (incremental by default; rebuild is the oracle).
    pub engine: EngineMode,
    /// Load-forecasting subsystem (default: off — fully reactive).
    pub forecast: ForecastConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            sptlb: SptlbConfig::default(),
            tick: Duration::from_millis(250),
            scenario: ScenarioConfig::default(),
            engine: EngineMode::Incremental,
            forecast: ForecastConfig::default(),
        }
    }
}

/// Tiers whose *pre-solve* utilization exceeds hard capacity on any
/// resource — the proactive loop's headline failure metric. Counted on
/// the incumbent under the round's fresh demands (before this round's
/// moves), so it measures what the *previous* decisions failed to
/// anticipate: a reactive policy can fix a breach only after this
/// counter has already seen it.
pub fn count_breach_tiers(initial_utilization: &[ResourceVec]) -> usize {
    initial_utilization
        .iter()
        .filter(|u| u.0.iter().any(|&x| x > 1.0))
        .count()
}

/// One round's record in the decision log.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: u32,
    /// Fleet events applied at the start of the round.
    pub n_events: usize,
    pub moves_executed: usize,
    pub score: f64,
    pub p99_latency_ms: f64,
    pub worst_imbalance: f64,
    pub pipeline_ms: f64,
    /// Wall-clock of the collection stage alone (the incremental
    /// engine's headline saving).
    pub collect_ms: f64,
    pub ticks_skipped: u32,
    /// Tiers whose pre-solve utilization breached hard capacity this
    /// round (see [`count_breach_tiers`]).
    pub breach_tiers: usize,
    /// sMAPE of last round's one-step demand forecasts against this
    /// round's registered demands (NaN → JSON null while forecasting is
    /// off or before the first comparison).
    pub forecast_smape: f64,
    /// §3.4 negotiation rounds the SPTLB ran this round (0 under the
    /// no/w_cnst variants, which skip the protocol).
    pub coop_rounds: u32,
    /// Negotiation rejections this round, by reason (the co-op kernel's
    /// uniform telemetry).
    pub coop_rejects: RejectCounts,
    /// Live avoid edges after the round: point (app, tier) avoids plus
    /// forbidden transitions still in their decay window.
    pub avoid_edges: usize,
    /// Escalation signals the avoid registry raised this round
    /// (persistent rejections that outlived their decay window).
    pub escalations: u32,
}

/// Bitwise equality on the float fields — the repo's determinism pins
/// compare records for *bit-identity*, and `forecast_smape` is NaN by
/// design while forecasting is off (a derived `PartialEq` would make
/// every such record unequal to itself).
impl PartialEq for RoundRecord {
    fn eq(&self, other: &Self) -> bool {
        self.round == other.round
            && self.n_events == other.n_events
            && self.moves_executed == other.moves_executed
            && self.score.to_bits() == other.score.to_bits()
            && self.p99_latency_ms.to_bits() == other.p99_latency_ms.to_bits()
            && self.worst_imbalance.to_bits() == other.worst_imbalance.to_bits()
            && self.pipeline_ms.to_bits() == other.pipeline_ms.to_bits()
            && self.collect_ms.to_bits() == other.collect_ms.to_bits()
            && self.ticks_skipped == other.ticks_skipped
            && self.breach_tiers == other.breach_tiers
            && self.forecast_smape.to_bits() == other.forecast_smape.to_bits()
            && self.coop_rounds == other.coop_rounds
            && self.coop_rejects == other.coop_rejects
            && self.avoid_edges == other.avoid_edges
            && self.escalations == other.escalations
    }
}

impl RoundRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("round", Json::num(self.round as f64)),
            ("n_events", Json::num(self.n_events as f64)),
            ("moves_executed", Json::num(self.moves_executed as f64)),
            ("score", Json::num(self.score)),
            ("p99_latency_ms", Json::num(self.p99_latency_ms)),
            ("worst_imbalance", Json::num(self.worst_imbalance)),
            ("pipeline_ms", Json::num(self.pipeline_ms)),
            ("collect_ms", Json::num(self.collect_ms)),
            ("ticks_skipped", Json::num(self.ticks_skipped as f64)),
            ("breach_tiers", Json::num(self.breach_tiers as f64)),
            ("forecast_smape", Json::num(self.forecast_smape)),
            ("coop_rounds", Json::num(self.coop_rounds as f64)),
            ("coop_rejects", self.coop_rejects.to_json()),
            ("avoid_edges", Json::num(self.avoid_edges as f64)),
            ("escalations", Json::num(self.escalations as f64)),
        ])
    }
}

/// Aggregated service metrics (the §3.3 "emitted as metrics in the
/// resource endpoint of the SPTLB").
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub imbalance: OnlineStats,
    pub latency_p99: OnlineStats,
    pub pipeline_ms: OnlineStats,
    pub collect_ms: OnlineStats,
    pub moves: OnlineStats,
    pub events: OnlineStats,
    /// Forecast accuracy over rounds where it was measurable.
    pub forecast_smape: OnlineStats,
    /// §3.4 negotiation rounds per coordinator round.
    pub coop_rounds: OnlineStats,
    /// Negotiation rejections per round (all reasons).
    pub coop_rejects: OnlineStats,
    /// Live avoid edges per round (point avoids + forbidden transitions).
    pub avoid_edges: OnlineStats,
    /// Escalation signals raised across the run.
    pub escalations: u32,
    pub rounds: u32,
    pub ticks_skipped: u32,
    /// Rounds with at least one pre-solve capacity breach — what the
    /// proactive path exists to minimize (`rust/tests/forecast.rs` pins
    /// forecast-aware < reactive on the diurnal scenario).
    pub breach_rounds: u32,
    /// Ingest-plane telemetry (admission sheds, batching, queue depth);
    /// all-zero when the coordinator runs the classic synchronous loop
    /// instead of the [`Service`](crate::service::Service) runtime.
    pub ingest: IngestStats,
}

/// Version tag of every metrics/decision-log JSON document this crate
/// writes ([`ServiceMetrics`], [`MultiRegionMetrics`], `GAP_report.json`).
/// History: 1 = original flat shape; 2 = service-runtime redesign
/// (ingest/shed counters, flattened config surface); 3 = observability
/// (optional `obs` object with span/sample percentiles and the
/// dropped-event counter when tracing is armed).
pub const METRICS_SCHEMA: u32 = 3;

/// A metrics document declared a `schema` this build does not understand
/// (missing, non-integer, zero, or newer than [`METRICS_SCHEMA`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SchemaError {
    /// The `schema` value found, if it was at least an integer.
    pub found: Option<u64>,
}

impl std::fmt::Display for SchemaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.found {
            Some(v) => write!(
                f,
                "unsupported metrics schema {v} (this build understands 1..={METRICS_SCHEMA})"
            ),
            None => write!(f, "metrics document has no integer `schema` field"),
        }
    }
}

impl std::error::Error for SchemaError {}

/// Validate a parsed metrics document's `schema` field. Accepts every
/// version this build can read (`1..=`[`METRICS_SCHEMA`]) and returns
/// it; rejects missing/zero/newer tags with a typed [`SchemaError`] so
/// callers fail loudly instead of misreading a shape they don't know.
pub fn check_metrics_schema(doc: &Json) -> Result<u32, SchemaError> {
    match doc.get("schema").as_u64() {
        Some(v) if (1..=METRICS_SCHEMA as u64).contains(&v) => Ok(v as u32),
        found => Err(SchemaError { found }),
    }
}

impl ServiceMetrics {
    pub fn to_json(&self) -> Json {
        self.to_json_with_obs(None)
    }

    /// Metrics JSON with an optional `obs` object folded in (the hub's
    /// span/sample histogram summary — see [`ObsHub::metrics_json`]).
    pub fn to_json_with_obs(&self, obs: Option<Json>) -> Json {
        let stat = |s: &OnlineStats| {
            Json::obj(vec![
                ("mean", Json::num(s.mean())),
                ("min", Json::num(s.min())),
                ("max", Json::num(s.max())),
                ("std", Json::num(s.std_dev())),
            ])
        };
        let mut fields = vec![
            ("schema", Json::num(METRICS_SCHEMA as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("ticks_skipped", Json::num(self.ticks_skipped as f64)),
            ("breach_rounds", Json::num(self.breach_rounds as f64)),
            ("imbalance", stat(&self.imbalance)),
            ("latency_p99_ms", stat(&self.latency_p99)),
            ("pipeline_ms", stat(&self.pipeline_ms)),
            ("collect_ms", stat(&self.collect_ms)),
            ("moves_per_round", stat(&self.moves)),
            ("events_per_round", stat(&self.events)),
            ("forecast_smape", stat(&self.forecast_smape)),
            ("coop_rounds", stat(&self.coop_rounds)),
            ("coop_rejects", stat(&self.coop_rejects)),
            ("avoid_edges", stat(&self.avoid_edges)),
            ("escalations", Json::num(self.escalations as f64)),
            ("ingest", self.ingest.to_json()),
        ];
        if let Some(o) = obs {
            fields.push(("obs", o));
        }
        Json::obj(fields)
    }
}

/// Negotiation telemetry of one round's report: (§3.4 rounds run,
/// rejections by reason). Zero under the no/w_cnst variants, which skip
/// the protocol entirely.
pub fn coop_telemetry(report: &BalanceReport) -> (u32, RejectCounts) {
    match &report.coop {
        Some(out) => (out.rounds.len() as u32, out.rejects()),
        None => (0, RejectCounts::default()),
    }
}

/// Skip-not-queue backpressure accounting: a round that overruns its tick
/// budget causes the next ⌊elapsed / tick⌋ ticks to be *skipped* — never
/// queued — so every round runs on fresh metrics (the paper's schedulers
/// "run on fresh data, never on a backlog"). A round that fits its tick
/// skips nothing.
pub fn ticks_skipped_for(elapsed: Duration, tick: Duration) -> u32 {
    if elapsed > tick {
        (elapsed.as_nanos() / tick.as_nanos().max(1)) as u32
    } else {
        0
    }
}

/// The leader loop.
pub struct Coordinator {
    pub config: CoordinatorConfig,
    state: FleetState,
    engine: FleetEngine,
    scenario: ScenarioGen,
    latency: LatencyMatrix,
    rounds_run: u32,
    pub log: Vec<RoundRecord>,
    /// Applied events per round — the replayable service journal.
    pub event_log: Vec<Vec<FleetEvent>>,
    pub metrics: ServiceMetrics,
    /// Trace/flight-recorder hub (None unless `--trace` armed it).
    hub: Option<ObsHub>,
    /// The coordinator's span recorder, parked here between rounds and
    /// installed into the running thread's slot for each round's scope.
    obs: Option<SpanRecorder>,
}

impl Coordinator {
    pub fn new(
        config: CoordinatorConfig,
        apps: Vec<App>,
        tiers: Vec<Tier>,
        latency: LatencyMatrix,
        initial: Assignment,
    ) -> Self {
        let state = FleetState::new(apps, tiers, initial);
        let engine =
            FleetEngine::with_forecast(config.engine, &config.sptlb, config.forecast.clone());
        let scenario = ScenarioGen::new(config.scenario.clone());
        Self {
            config,
            state,
            engine,
            scenario,
            latency,
            rounds_run: 0,
            log: Vec::new(),
            event_log: Vec::new(),
            metrics: ServiceMetrics::default(),
            hub: None,
            obs: None,
        }
    }

    /// Arm tracing: the coordinator records onto [`obs::GLOBAL_TRACK`]
    /// and harvests into `hub` after every round.
    pub fn attach_obs(&mut self, hub: ObsHub) {
        self.obs = Some(hub.recorder(obs::GLOBAL_TRACK));
        self.hub = Some(hub);
    }

    /// The attached hub, if tracing is armed.
    pub fn obs_hub(&self) -> Option<&ObsHub> {
        self.hub.as_ref()
    }

    /// Fire a flight-recorder trigger (dumps the last rounds' ring once
    /// per trigger kind — see [`ObsHub::trigger`]).
    pub fn obs_trigger(&mut self, trigger: FlightTrigger, note: &str) {
        if let Some(hub) = self.hub.as_mut() {
            hub.trigger(trigger, note);
        }
    }

    /// Service metrics with the hub's `obs` summary folded in when
    /// tracing is armed.
    pub fn metrics_json(&self) -> Json {
        self.metrics.to_json_with_obs(self.hub.as_ref().map(ObsHub::metrics_json))
    }

    /// Install the parked recorder into this thread's slot for the round
    /// about to run (no-op when tracing is off).
    fn obs_install_round(&mut self) {
        if let Some(mut rec) = self.obs.take() {
            rec.set_round(self.rounds_run);
            let displaced = obs::swap(Some(rec));
            debug_assert!(displaced.is_none(), "coordinator thread slot was free");
        }
    }

    /// Uninstall the recorder, park it, and harvest the round's events
    /// into the hub (flight ring + trace file + histograms).
    fn obs_harvest_round(&mut self, round: u32) {
        if let Some(rec) = obs::uninstall() {
            self.obs = Some(rec);
        }
        if let (Some(hub), Some(rec)) = (self.hub.as_mut(), self.obs.as_mut()) {
            hub.harvest(rec);
            hub.commit_round(round);
        }
    }

    pub fn from_testbed(config: CoordinatorConfig, bed: crate::workload::TestBed) -> Self {
        Self::new(config, bed.apps, bed.tiers, bed.latency, bed.initial)
    }

    pub fn current_assignment(&self) -> &Assignment {
        self.state.assignment()
    }

    pub fn fleet(&self) -> &FleetState {
        &self.state
    }

    /// Run `n_rounds` balancing rounds, drawing events from the
    /// configured scenario. Returns the per-round reports.
    pub fn run(&mut self, n_rounds: u32) -> Vec<BalanceReport> {
        let mut reports = Vec::with_capacity(n_rounds as usize);
        for _ in 0..n_rounds {
            let events = self.scenario.events_for_round(
                self.rounds_run,
                self.state.apps(),
                self.state.tiers(),
                self.state.next_app_id(),
            );
            reports.push(self.round_once(events));
        }
        reports
    }

    /// Replay a recorded event log (one `Vec<FleetEvent>` per round)
    /// instead of drawing from the scenario — the determinism tests'
    /// entry point and the basis for incident reproduction.
    pub fn run_events(&mut self, rounds: &[Vec<FleetEvent>]) -> Vec<BalanceReport> {
        rounds.iter().map(|ev| self.round_once(ev.clone())).collect()
    }

    fn round_once(&mut self, events: Vec<FleetEvent>) -> BalanceReport {
        let round = self.rounds_run;
        let installed_here = self.obs.is_some();
        if installed_here {
            self.obs_install_round();
        }
        obs::begin(obs::SpanKind::GlobalRound);
        let sw = Stopwatch::start();
        let delta = self.state.apply_all(&events);
        let (report, moves) = self.engine.round(
            &mut self.state,
            &events,
            &delta,
            &self.config.sptlb,
            &self.latency,
            round,
        );

        // ---- backpressure accounting.
        let ticks_skipped = ticks_skipped_for(sw.elapsed(), self.config.tick);

        let worst = crate::hierarchy::variants::worst_imbalance(
            &report.projected_utilization,
            crate::hierarchy::variants::BALANCED_TARGET,
        );
        let breach_tiers = count_breach_tiers(&report.initial_utilization);
        let forecast_smape = self.engine.last_smape();
        let (coop_rounds, coop_rejects) = coop_telemetry(&report);
        let avoid_edges = self.engine.avoid_edge_count();
        let escalations = self.engine.last_escalations();
        // Single-region mode has no scheduler layer above to consume the
        // pressure signals: drain them each round (they are logged via
        // `escalations` above) so a long-lived service never accumulates
        // a stale backlog that a later-attached global layer would
        // misread as fresh pressure.
        self.engine.take_escalations();
        let record = RoundRecord {
            round,
            n_events: events.len(),
            moves_executed: moves.len(),
            score: report.solution.score,
            p99_latency_ms: report.p99_latency_ms,
            worst_imbalance: worst,
            pipeline_ms: report.pipeline_ms,
            collect_ms: report.collect_ms,
            ticks_skipped,
            breach_tiers,
            forecast_smape,
            coop_rounds,
            coop_rejects,
            avoid_edges,
            escalations,
        };
        self.metrics.rounds += 1;
        self.metrics.ticks_skipped += ticks_skipped;
        if breach_tiers > 0 {
            self.metrics.breach_rounds += 1;
        }
        if forecast_smape.is_finite() {
            self.metrics.forecast_smape.push(forecast_smape);
        }
        self.metrics.coop_rounds.push(coop_rounds as f64);
        self.metrics.coop_rejects.push(coop_rejects.total() as f64);
        self.metrics.avoid_edges.push(avoid_edges as f64);
        self.metrics.escalations += escalations;
        self.metrics.imbalance.push(worst);
        self.metrics.latency_p99.push(report.p99_latency_ms);
        self.metrics.pipeline_ms.push(report.pipeline_ms);
        self.metrics.collect_ms.push(report.collect_ms);
        self.metrics.moves.push(moves.len() as f64);
        self.metrics.events.push(events.len() as f64);
        log::info!(
            "round {round}: {} events, {} moves, imbalance {:.3}, p99 {:.0}ms, {:.0}ms ({:.0}ms collect)",
            events.len(),
            moves.len(),
            worst,
            report.p99_latency_ms,
            report.pipeline_ms,
            report.collect_ms,
        );
        self.log.push(record);
        self.event_log.push(events);
        self.rounds_run += 1;
        obs::end(obs::SpanKind::GlobalRound);
        if installed_here {
            self.obs_harvest_round(round);
        }
        report
    }

    /// Decision log as a JSON array (persisted by the CLI).
    pub fn log_json(&self) -> Json {
        Json::arr(self.log.iter().map(|r| r.to_json()))
    }

    /// Applied events per round as JSON (the replayable journal).
    pub fn event_log_json(&self) -> Json {
        Json::arr(
            self.event_log
                .iter()
                .map(|evs| Json::arr(evs.iter().map(|e| e.to_json()))),
        )
    }
}

/// Parse a journal written by [`Coordinator::event_log_json`] back into
/// the per-round event lists [`Coordinator::run_events`] consumes — the
/// incident-reproduction path for `--event-log` files.
pub fn parse_event_log(j: &Json) -> Option<Vec<Vec<FleetEvent>>> {
    j.as_arr()?
        .iter()
        .map(|round| {
            round
                .as_arr()?
                .iter()
                .map(FleetEvent::from_json)
                .collect::<Option<Vec<_>>>()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate, WorkloadSpec};
    use std::time::Duration;

    fn coordinator(tune: impl FnOnce(&mut CoordinatorConfig)) -> Coordinator {
        let bed = generate(&WorkloadSpec::small());
        let mut cfg = CoordinatorConfig {
            sptlb: SptlbConfig {
                timeout: Duration::from_millis(25),
                ..SptlbConfig::default()
            },
            ..CoordinatorConfig::default()
        };
        tune(&mut cfg);
        Coordinator::from_testbed(cfg, bed)
    }

    #[test]
    fn runs_rounds_and_logs() {
        let mut c = coordinator(|_| {});
        let reports = c.run(3);
        assert_eq!(reports.len(), 3);
        assert_eq!(c.log.len(), 3);
        assert_eq!(c.event_log.len(), 3);
        assert_eq!(c.metrics.rounds, 3);
        assert!(c.metrics.imbalance.mean().is_finite());
        assert!(c.metrics.collect_ms.mean() >= 0.0);
    }

    #[test]
    fn assignment_carries_across_rounds() {
        let mut c = coordinator(|cfg| cfg.scenario = ScenarioConfig::steady());
        let reports = c.run(1);
        let after = c.current_assignment().clone();
        assert_eq!(&after, &reports[0].solution.assignment);
        // Round 2's problem must use round 1's output as incumbent.
        let r2 = c.run(1);
        assert_eq!(r2[0].problem.initial, after);
    }

    #[test]
    fn drift_changes_demands() {
        let mut c = coordinator(|cfg| {
            cfg.scenario = ScenarioConfig { drift_sigma: 0.2, ..ScenarioConfig::drift() };
        });
        let before: f64 = c.fleet().apps().iter().map(|a| a.demand.cpu()).sum();
        c.run(1);
        let after: f64 = c.fleet().apps().iter().map(|a| a.demand.cpu()).sum();
        assert_ne!(before, after);
    }

    #[test]
    fn arrivals_grow_population() {
        let mut c = coordinator(|cfg| {
            cfg.scenario = ScenarioConfig {
                drift_sigma: 0.0,
                arrival_prob: 1.0,
                departure_prob: 0.0,
                ..ScenarioConfig::churn()
            };
        });
        let n0 = c.fleet().n_apps();
        c.run(2);
        assert_eq!(c.fleet().n_apps(), n0 + 2);
        assert_eq!(c.current_assignment().n_apps(), n0 + 2);
    }

    #[test]
    fn churn_keeps_ids_unique_and_monotonic() {
        // The satellite regression: with departures in play, arrivals
        // must never reuse a live id (the old `AppId(apps.len())` bug).
        let mut c = coordinator(|cfg| {
            cfg.scenario = ScenarioConfig {
                drift_sigma: 0.05,
                arrival_prob: 0.9,
                departure_prob: 0.9,
                ..ScenarioConfig::churn()
            };
        });
        c.run(8);
        let apps = c.fleet().apps();
        assert!(apps.windows(2).all(|w| w[0].id < w[1].id), "ids stay sorted+unique");
        assert_eq!(c.current_assignment().n_apps(), apps.len());
        // At least one departure and one arrival actually happened.
        let n_arrivals: usize = c
            .event_log
            .iter()
            .flatten()
            .filter(|e| matches!(e, FleetEvent::Arrival { .. }))
            .count();
        let n_departures: usize = c
            .event_log
            .iter()
            .flatten()
            .filter(|e| matches!(e, FleetEvent::Departure { .. }))
            .count();
        assert!(n_arrivals > 0 && n_departures > 0, "churn scenario must churn");
    }

    #[test]
    fn backpressure_counts_skipped_ticks() {
        let mut c = coordinator(|cfg| {
            cfg.tick = Duration::from_nanos(100); // force overrun
        });
        c.run(1);
        assert!(c.log[0].ticks_skipped >= 1);
        assert!(c.metrics.ticks_skipped >= 1);
    }

    #[test]
    fn ticks_skipped_semantics_pinned() {
        // Regression pin for the skip-not-queue semantics: within-budget
        // rounds skip nothing (including the exact-boundary case), and an
        // overrun skips ⌊elapsed / tick⌋ subsequent ticks.
        let ms = Duration::from_millis;
        assert_eq!(ticks_skipped_for(ms(100), ms(250)), 0);
        assert_eq!(ticks_skipped_for(ms(250), ms(250)), 0, "exact fit is on time");
        assert_eq!(ticks_skipped_for(ms(251), ms(250)), 1);
        assert_eq!(ticks_skipped_for(ms(600), ms(250)), 2);
        assert_eq!(ticks_skipped_for(ms(2500), ms(250)), 10);
        assert_eq!(ticks_skipped_for(Duration::ZERO, ms(250)), 0);
    }

    #[test]
    fn generous_tick_budget_skips_nothing() {
        let mut c = coordinator(|cfg| cfg.tick = Duration::from_secs(3600));
        c.run(3);
        assert_eq!(c.metrics.ticks_skipped, 0);
        assert!(c.log.iter().all(|r| r.ticks_skipped == 0));
    }

    #[test]
    fn skipped_tick_aggregate_matches_decision_log() {
        // The service metric must be exactly the sum of the per-round
        // decision-log entries — skipped ticks are accounted, not queued
        // as extra rounds.
        let mut c = coordinator(|cfg| cfg.tick = Duration::from_micros(50));
        let reports = c.run(4);
        assert_eq!(reports.len(), 4, "skipped ticks never add rounds");
        let from_log: u32 = c.log.iter().map(|r| r.ticks_skipped).sum();
        assert_eq!(c.metrics.ticks_skipped, from_log);
    }

    #[test]
    fn log_json_parses_and_carries_collect_ms() {
        let mut c = coordinator(|_| {});
        c.run(2);
        let j = c.log_json().pretty();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert!(arr[0].get("collect_ms").as_f64().is_some());
        assert!(arr[0].get("n_events").as_f64().is_some());
        let m = c.metrics.to_json().to_string();
        let parsed = crate::util::json::Json::parse(&m).unwrap();
        assert!(parsed.get("collect_ms").get("mean").as_f64().is_some());
        let ev = c.event_log_json().to_string();
        assert!(crate::util::json::Json::parse(&ev).is_ok());
    }

    #[test]
    fn metrics_json_carries_schema_version_and_ingest_counters() {
        // Round-trip pin for the schema-3 shape: downstream parsers key
        // on the version field to detect the redesigned document.
        let mut c = coordinator(|_| {});
        c.run(1);
        c.metrics.ingest.shed.unknown_app = 3;
        let j = Json::parse(&c.metrics.to_json().to_string()).unwrap();
        assert_eq!(j.get("schema").as_u64(), Some(super::METRICS_SCHEMA as u64));
        assert_eq!(j.get("schema").as_u64(), Some(3));
        assert_eq!(j.get("ingest").get("shed").get("unknown_app").as_u64(), Some(3));
        assert_eq!(j.get("ingest").get("fast_rounds").as_u64(), Some(0));
        // Without an attached hub the `obs` object is absent.
        assert!(j.get("obs").as_obj().is_none());
        assert_eq!(check_metrics_schema(&j), Ok(3));
    }

    #[test]
    fn schema_validation_rejects_unknown_documents() {
        // Every version this build can read round-trips through the
        // checker; missing/zero/future tags fail with the typed error.
        for v in 1..=METRICS_SCHEMA {
            let doc = Json::parse(&format!("{{\"schema\": {v}}}")).unwrap();
            assert_eq!(check_metrics_schema(&doc), Ok(v));
        }
        let future = Json::parse(&format!("{{\"schema\": {}}}", METRICS_SCHEMA + 1)).unwrap();
        let err = check_metrics_schema(&future).unwrap_err();
        assert_eq!(err.found, Some(METRICS_SCHEMA as u64 + 1));
        assert!(err.to_string().contains("unsupported metrics schema"));
        let missing = Json::parse("{\"rounds\": 5}").unwrap();
        let err = check_metrics_schema(&missing).unwrap_err();
        assert_eq!(err.found, None);
        let zero = Json::parse("{\"schema\": 0}").unwrap();
        assert!(check_metrics_schema(&zero).is_err());
    }

    #[test]
    fn traced_coordinator_folds_obs_into_metrics_and_stays_deterministic() {
        use std::time::Duration;
        let mut plain = coordinator(|cfg| cfg.sptlb.timeout = Duration::from_secs(2));
        let mut traced = coordinator(|cfg| cfg.sptlb.timeout = Duration::from_secs(2));
        traced.attach_obs(ObsHub::new(obs::TraceLevel::Decisions, None).unwrap());
        plain.run(4);
        let journal = plain.event_log.clone();
        traced.run_events(&journal);
        // Deterministic decision fields only — `RoundRecord::eq` is
        // bit-exact and includes wall-clock stage timings, which two
        // separate runs legitimately differ on.
        assert_eq!(plain.log.len(), traced.log.len());
        for (a, b) in plain.log.iter().zip(&traced.log) {
            assert_eq!(a.score.to_bits(), b.score.to_bits(), "tracing perturbed round {}", a.round);
            assert_eq!(a.moves_executed, b.moves_executed);
            assert_eq!(a.n_events, b.n_events);
            assert_eq!(a.avoid_edges, b.avoid_edges);
            assert_eq!(a.escalations, b.escalations);
        }
        let j = Json::parse(&traced.metrics_json().to_string()).unwrap();
        assert_eq!(check_metrics_schema(&j), Ok(3));
        let o = j.get("obs");
        assert_eq!(o.get("level").as_str(), Some("decisions"));
        assert!(o.get("spans").get("global_round").get("count").as_u64().unwrap_or(0) >= 4);
        assert!(o.get("dropped_events").as_u64().is_some());
    }

    #[test]
    fn breach_tier_counting() {
        let utils = vec![
            ResourceVec::new(0.5, 0.9, 1.0),
            ResourceVec::new(1.2, 0.1, 0.1),
            ResourceVec::new(0.2, 1.01, 0.3),
        ];
        assert_eq!(count_breach_tiers(&utils), 2, "exactly-at-capacity is not a breach");
        assert_eq!(count_breach_tiers(&[]), 0);
    }

    #[test]
    fn forecasting_populates_accuracy_and_breach_metrics() {
        use crate::forecast::{ForecastConfig, ForecasterKind};
        let mut c = coordinator(|cfg| {
            cfg.scenario = ScenarioConfig::diurnal().with_seed(5);
            cfg.forecast = ForecastConfig {
                forecaster: ForecasterKind::NaiveLast,
                ..ForecastConfig::default()
            };
        });
        c.run(4);
        // Round 0 has nothing to compare against; later rounds do (the
        // diurnal wave drifts every app every round, so naive-last is
        // always measurably wrong but finite).
        assert!(c.log[0].forecast_smape.is_nan());
        assert!(c.log[1..].iter().all(|r| r.forecast_smape.is_finite()));
        assert_eq!(c.metrics.forecast_smape.count(), 3);
        // The new fields serialize (NaN → JSON null) and parse back.
        let parsed = Json::parse(&c.log_json().pretty()).unwrap();
        let rounds = parsed.as_arr().unwrap();
        assert!(rounds[0].get("breach_tiers").as_f64().is_some());
        assert!(rounds[0].get("forecast_smape").as_f64().is_none(), "NaN is null");
        assert!(rounds[1].get("forecast_smape").as_f64().is_some());
        let m = Json::parse(&c.metrics.to_json().to_string()).unwrap();
        assert!(m.get("breach_rounds").as_f64().is_some());
        assert!(m.get("forecast_smape").get("mean").as_f64().is_some());
    }

    #[test]
    fn replaying_the_event_log_reproduces_decisions() {
        // The replay goes through the on-disk representation: journal →
        // JSON text → parse_event_log → run_events must reproduce the
        // recorded decision log bit-for-bit (incident reproduction).
        let mut a = coordinator(|cfg| {
            cfg.sptlb.timeout = Duration::from_secs(2);
            cfg.scenario = ScenarioConfig {
                drift_fraction: 0.5,
                arrival_prob: 0.7,
                departure_prob: 0.5,
                ..ScenarioConfig::churn()
            };
        });
        a.run(5);
        let journal_text = a.event_log_json().pretty();
        let journal = parse_event_log(&Json::parse(&journal_text).unwrap())
            .expect("journal parses back");
        assert_eq!(journal, a.event_log, "JSON roundtrip preserves the journal exactly");

        let mut b = coordinator(|cfg| {
            cfg.sptlb.timeout = Duration::from_secs(2);
            cfg.scenario = ScenarioConfig::steady(); // replay ignores it
        });
        b.run_events(&journal);
        assert_eq!(a.event_log, b.event_log);
        for (ra, rb) in a.log.iter().zip(&b.log) {
            assert_eq!(ra.score, rb.score, "round {}", ra.round);
            assert_eq!(ra.moves_executed, rb.moves_executed);
            assert_eq!(ra.worst_imbalance, rb.worst_imbalance);
        }
        assert_eq!(a.current_assignment(), b.current_assignment());
    }
}
