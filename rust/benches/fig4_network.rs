//! Figure 4 regeneration: worst-case (p99) network latency per hierarchy
//! integration variant × solver type × timeout, with an ASCII scatter
//! matching the paper's plot (x = time-to-solution, y = p99 latency).
//!
//! Run: cargo bench --bench fig4_network
//! Paper-scale timeouts: SPTLB_PAPER_TIMEOUTS=1 cargo bench --bench fig4_network

use sptlb::bench::{bench_seeds, timeout_ladder};
use sptlb::hierarchy::variants::Variant;
use sptlb::rebalancer::solution::SolverKind;
use sptlb::report::ascii::scatter;
use sptlb::report::{fig4_rows, SweepRow};
use sptlb::workload::{generate, WorkloadSpec};

fn main() {
    println!("=== Figure 4: p99 network latency across SPTLB integrations ===");
    let timeouts = timeout_ladder();
    println!("timeouts {timeouts:?} (paper: 30s/60s/10m/30m)\n");

    let mut all_rows: Vec<SweepRow> = Vec::new();
    for seed in bench_seeds() {
        let bed = generate(&WorkloadSpec::paper().with_seed(seed));
        let rows = sptlb::report::sweep(&bed, &timeouts, 0.10, seed);
        all_rows.extend(rows);
    }
    print!("{}", fig4_rows(&all_rows));

    // ASCII scatter (paper: triangles = local, dots = optimal).
    let pts = |variant: Variant, solver: SolverKind| -> Vec<(f64, f64)> {
        all_rows
            .iter()
            .filter(|r| r.variant == variant && r.solver == solver && r.n_moves > 0)
            .map(|r| (r.time_to_solution_ms, r.p99_latency_ms))
            .collect()
    };
    let series = [
        ("no_cnst/local", 'n', pts(Variant::NoCnst, SolverKind::LocalSearch)),
        ("no_cnst/opt", 'N', pts(Variant::NoCnst, SolverKind::OptimalSearch)),
        ("w_cnst/local", 'w', pts(Variant::WCnst, SolverKind::LocalSearch)),
        ("w_cnst/opt", 'W', pts(Variant::WCnst, SolverKind::OptimalSearch)),
        ("manual/local", 'm', pts(Variant::ManualCnst, SolverKind::LocalSearch)),
        ("manual/opt", 'M', pts(Variant::ManualCnst, SolverKind::OptimalSearch)),
    ];
    println!();
    print!(
        "{}",
        scatter(
            "Figure 4: worst-case move latency vs time-to-solution",
            &series,
            "time to solution (ms)",
            "p99 latency (ms)",
            64,
            16,
        )
    );

    // Headline check (printed, asserted in figures_integration tests):
    let mean = |v: Variant| {
        let xs: Vec<f64> = all_rows
            .iter()
            .filter(|r| r.variant == v && r.n_moves > 0)
            .map(|r| r.p99_latency_ms)
            .collect();
        xs.iter().sum::<f64>() / xs.len().max(1) as f64
    };
    println!(
        "\nmean p99 latency: no_cnst {:.0} ms | w_cnst {:.0} ms | manual_cnst {:.0} ms",
        mean(Variant::NoCnst),
        mean(Variant::WCnst),
        mean(Variant::ManualCnst)
    );
    println!("expected shape (paper): w_cnst lowest, manual close, no_cnst highest");
}
