//! Mini property-based testing framework (proptest is not available
//! offline). Provides seeded generators, a `forall` runner with failure
//! reporting (seed + case index for replay), and greedy input shrinking
//! for a few common shapes.
//!
//! Usage:
//! ```ignore
//! propcheck::forall(200, |rng| gen_problem(rng), |p| check_invariant(p));
//! ```

use crate::util::prng::Pcg64;

/// Outcome of a single property evaluation.
pub enum Check {
    Pass,
    Fail(String),
}

impl Check {
    pub fn from_bool(ok: bool, msg: &str) -> Check {
        if ok {
            Check::Pass
        } else {
            Check::Fail(msg.to_string())
        }
    }

    pub fn pass() -> Check {
        Check::Pass
    }

    pub fn fail(msg: &str) -> Check {
        Check::Fail(msg.to_string())
    }
}

/// Run `prop` over `cases` generated inputs. Panics (test failure) with the
/// replay seed on the first failing case.
pub fn forall<T: std::fmt::Debug>(
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Check,
) {
    forall_seeded(0xC0FFEE, cases, &mut gen, &mut prop);
}

/// `forall` with an explicit base seed (reported on failure for replay).
pub fn forall_seeded<T: std::fmt::Debug>(
    base_seed: u64,
    cases: usize,
    gen: &mut impl FnMut(&mut Pcg64) -> T,
    prop: &mut impl FnMut(&T) -> Check,
) {
    for case in 0..cases {
        let mut rng = Pcg64::new(base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen(&mut rng);
        if let Check::Fail(msg) = prop(&input) {
            panic!(
                "property failed (seed={base_seed:#x}, case={case}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Greedy shrinking for vector inputs: repeatedly tries dropping chunks and
/// single elements while the property still fails, then reports the minimal
/// failing input. Use for debugging; `forall` is the day-to-day runner.
pub fn shrink_vec<T: Clone + std::fmt::Debug>(
    mut input: Vec<T>,
    still_fails: impl Fn(&[T]) -> bool,
) -> Vec<T> {
    debug_assert!(still_fails(&input), "shrink_vec called with passing input");
    loop {
        let mut shrunk = false;
        // Halves first.
        let mut chunk = input.len() / 2;
        while chunk >= 1 {
            let mut i = 0;
            while i + chunk <= input.len() {
                let mut candidate = input.clone();
                candidate.drain(i..i + chunk);
                if !candidate.is_empty() && still_fails(&candidate) {
                    input = candidate;
                    shrunk = true;
                } else {
                    i += chunk;
                }
            }
            chunk /= 2;
        }
        if !shrunk {
            return input;
        }
    }
}

/// Common generator helpers.
pub mod gen {
    use super::*;

    pub fn usize_in(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        rng.range(lo, hi)
    }

    pub fn f64_in(rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
        rng.uniform(lo, hi)
    }

    pub fn vec_of<T>(rng: &mut Pcg64, len: usize, mut f: impl FnMut(&mut Pcg64) -> T) -> Vec<T> {
        (0..len).map(|_| f(rng)).collect()
    }

    /// Non-empty subset of 0..n as a sorted vec.
    pub fn subset(rng: &mut Pcg64, n: usize) -> Vec<usize> {
        assert!(n > 0);
        loop {
            let s: Vec<usize> = (0..n).filter(|_| rng.chance(0.5)).collect();
            if !s.is_empty() {
                return s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            100,
            |rng| rng.range(0, 100),
            |&x| Check::from_bool(x < 100, "in range"),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure_with_seed() {
        forall(
            100,
            |rng| rng.range(0, 100),
            |&x| Check::from_bool(x < 50, "must be small"),
        );
    }

    #[test]
    fn shrink_finds_minimal_counterexample() {
        // Property "no element >= 90" fails; minimal failing vec is one
        // offending element.
        let input: Vec<u64> = (0..100).collect();
        let minimal = shrink_vec(input, |xs| xs.iter().any(|&x| x >= 90));
        assert_eq!(minimal.len(), 1);
        assert!(minimal[0] >= 90);
    }

    #[test]
    fn subset_is_nonempty_sorted_unique() {
        let mut rng = Pcg64::new(3);
        for _ in 0..50 {
            let s = gen::subset(&mut rng, 8);
            assert!(!s.is_empty());
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&x| x < 8));
        }
    }
}
