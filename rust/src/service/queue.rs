//! The bounded, mutex-free ingest queue: the generic Vyukov ring
//! ([`crate::util::ring::Ring`]) specialized to [`FleetEvent`].
//! Producer threads `try_push` concurrently; the service loop (or, with
//! `--regions N`, the region worker owning this queue) `try_pop`s
//! during its drain window. Capacity is fixed at construction (rounded
//! up to a power of two) — a full queue is the backpressure signal,
//! surfaced to the producer as the rejected event so the shed/block
//! policy can decide what to do with it. Push and pop never touch the
//! allocator, so the warm ingest round's zero-allocation contract
//! extends through the queue.

use crate::model::FleetEvent;
use crate::util::ring::Ring;

/// Bounded lock-free multi-producer event queue.
pub type IngestQueue = Ring<FleetEvent>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{AppId, ResourceVec};
    use std::sync::Arc;

    fn drift(id: usize, cpu: f64) -> FleetEvent {
        FleetEvent::DemandDrift {
            app: AppId::from_usize(id),
            demand: ResourceVec::new(cpu, 1.0, 1.0),
        }
    }

    fn drift_id(ev: &FleetEvent) -> usize {
        match ev {
            FleetEvent::DemandDrift { app, .. } => app.idx(),
            other => panic!("unexpected event {other:?}"),
        }
    }

    #[test]
    fn fifo_order_single_thread() {
        let q = IngestQueue::with_capacity(8);
        assert_eq!(q.capacity(), 8);
        for i in 0..5 {
            q.try_push(drift(i, 1.0)).unwrap();
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(drift_id(&q.try_pop().unwrap()), i);
        }
        assert!(q.try_pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn full_queue_returns_the_event_to_the_producer() {
        let q = IngestQueue::with_capacity(2);
        q.try_push(drift(0, 1.0)).unwrap();
        q.try_push(drift(1, 1.0)).unwrap();
        let rejected = q.try_push(drift(2, 7.5)).unwrap_err();
        assert_eq!(drift_id(&rejected), 2);
        // Popping one frees a slot for exactly the rejected event.
        assert_eq!(drift_id(&q.try_pop().unwrap()), 0);
        q.try_push(rejected).unwrap();
        assert_eq!(drift_id(&q.try_pop().unwrap()), 1);
        assert_eq!(drift_id(&q.try_pop().unwrap()), 2);
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        assert_eq!(IngestQueue::with_capacity(0).capacity(), 2);
        assert_eq!(IngestQueue::with_capacity(3).capacity(), 4);
        assert_eq!(IngestQueue::with_capacity(1000).capacity(), 1024);
    }

    #[test]
    fn concurrent_producers_lose_no_accepted_event() {
        const PRODUCERS: usize = 4;
        const PER_PRODUCER: usize = 500;
        let q = Arc::new(IngestQueue::with_capacity(64));
        let handles: Vec<_> = (0..PRODUCERS)
            .map(|w| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..PER_PRODUCER {
                        let mut ev = drift(w * PER_PRODUCER + i, 1.0);
                        // Block-style retry: every event must land.
                        loop {
                            match q.try_push(ev) {
                                Ok(()) => break,
                                Err(back) => {
                                    ev = back;
                                    std::thread::yield_now();
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        let mut seen = vec![false; PRODUCERS * PER_PRODUCER];
        let mut popped = 0;
        while popped < PRODUCERS * PER_PRODUCER {
            match q.try_pop() {
                Some(ev) => {
                    let id = drift_id(&ev);
                    assert!(!seen[id], "event {id} delivered twice");
                    seen[id] = true;
                    popped += 1;
                }
                None => std::thread::yield_now(),
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(seen.iter().all(|&s| s), "every accepted event delivered");
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn drop_releases_undelivered_events() {
        let q = IngestQueue::with_capacity(8);
        for i in 0..6 {
            q.try_push(drift(i, 1.0)).unwrap();
        }
        drop(q); // must not leak the six undelivered events
    }
}
