//! Quickstart: the 20-line tour of the public API.
//!
//! Generates the paper-shaped testbed, runs one SPTLB balancing pass, and
//! prints before/after tier utilizations.
//!
//! Usage: cargo run --release --example quickstart

use sptlb::metadata::MetadataStore;
use sptlb::sptlb::{Sptlb, SptlbConfig};
use sptlb::workload::{generate, WorkloadSpec};

fn main() {
    // 1. A testbed: 5 tiers, 120 heavy-tailed apps, paper SLO mapping,
    //    tier 3 initially over-utilized (swap in your own fleet here).
    let bed = generate(&WorkloadSpec::paper());
    let store = MetadataStore::from_apps(bed.apps.clone()).expect("unique app ids");

    // 2. The balancer with default knobs (LocalSearch, 10% movement,
    //    manual_cnst co-operation with the region/host schedulers).
    let sptlb = Sptlb::new(SptlbConfig::default());

    // 3. One pipeline run: collect -> construct -> solve -> execute.
    let report = sptlb.balance(&store, &bed.tiers, &bed.latency, &bed.initial);

    println!("moves recommended: {}", report.solution.moves(&report.problem).len());
    println!("worst-case move latency (p99): {:.0} ms", report.p99_latency_ms);
    println!("\ntier     cpu%  (initial -> projected)");
    for (i, (before, after)) in report
        .initial_utilization
        .iter()
        .zip(&report.projected_utilization)
        .enumerate()
    {
        println!(
            "tier{}:  {:5.1} -> {:5.1}",
            i + 1,
            before.cpu() * 100.0,
            after.cpu() * 100.0
        );
    }
    assert!(report.violations.iter().all(|v| {
        matches!(
            v,
            sptlb::rebalancer::Violation::CapacityExceeded { .. }
        )
    }));
    println!("\nquickstart OK");
}
