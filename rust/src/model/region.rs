//! Regions: the geography the lower-level schedulers (§3.4, Fig. 2) care
//! about. A tier owns machines in a set of regions; moving an app to a tier
//! without presence near its data source incurs the network cost Fig. 4
//! measures.
//!
//! Two levels use this module. *Micro* regions are the per-testbed
//! geography a tier's [`RegionSet`] spans. *Global* regions are one level
//! up: each runs its own SPTLB over its own tiers, and the
//! [`GlobalScheduler`](crate::hierarchy::global) balances apps across them
//! using the [`InterRegionMatrix`] wide-area latency/egress costs and the
//! [`RegionTopology`] per-region tier sets.

use crate::model::tier::TierId;
use crate::util::json::Json;
use crate::util::prng::Pcg64;
use std::fmt;

/// Dense region identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RegionId(pub usize);

impl RegionId {
    /// Use this id as a dense array index (mirrors `AppId::idx`).
    #[inline]
    pub fn idx(self) -> usize {
        self.0
    }
}

impl fmt::Display for RegionId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "region{}", self.0)
    }
}

/// A sorted set of regions (small, so a sorted Vec beats a HashSet).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct RegionSet {
    regions: Vec<RegionId>,
}

impl RegionSet {
    pub fn new(mut regions: Vec<RegionId>) -> Self {
        regions.sort_unstable();
        regions.dedup();
        Self { regions }
    }

    pub fn from_indices(idx: impl IntoIterator<Item = usize>) -> Self {
        Self::new(idx.into_iter().map(RegionId).collect())
    }

    pub fn contains(&self, r: RegionId) -> bool {
        self.regions.binary_search(&r).is_ok()
    }

    pub fn len(&self) -> usize {
        self.regions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.regions.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = RegionId> + '_ {
        self.regions.iter().copied()
    }

    pub fn as_slice(&self) -> &[RegionId] {
        &self.regions
    }

    /// Remove a region (fleet `RegionOutage` event). Returns true if the
    /// region was present.
    pub fn remove(&mut self, r: RegionId) -> bool {
        match self.regions.binary_search(&r) {
            Ok(i) => {
                self.regions.remove(i);
                true
            }
            Err(_) => false,
        }
    }

    /// |self ∩ other|.
    pub fn intersection_size(&self, other: &RegionSet) -> usize {
        self.regions.iter().filter(|r| other.contains(**r)).count()
    }

    /// The w_cnst validity test (§4.2.2): >50% of this set's regions must
    /// overlap with `other` for a transition to be allowed.
    pub fn majority_overlap(&self, other: &RegionSet) -> bool {
        if self.is_empty() {
            return false;
        }
        2 * self.intersection_size(other) > self.len()
    }

    /// Serialize as a sorted array of region indices.
    pub fn to_json(&self) -> Json {
        Json::arr(self.regions.iter().map(|r| Json::num(r.0 as f64)))
    }

    pub fn from_json(j: &Json) -> Option<RegionSet> {
        let arr = j.as_arr()?;
        let idx = arr.iter().map(|v| v.as_usize()).collect::<Option<Vec<_>>>()?;
        Some(RegionSet::from_indices(idx))
    }
}

impl FromIterator<RegionId> for RegionSet {
    fn from_iter<I: IntoIterator<Item = RegionId>>(iter: I) -> Self {
        Self::new(iter.into_iter().collect())
    }
}

/// Wide-area costs between *global* regions: a symmetric latency matrix
/// (ms) plus a per-unit-demand egress cost. The global scheduler consults
/// both before proposing a cross-region migration — a move that would
/// stream data across an expensive or slow pairing is never proposed.
#[derive(Debug, Clone, PartialEq)]
pub struct InterRegionMatrix {
    n: usize,
    latency_ms: Vec<f64>, // row-major n×n, symmetrized, zero diagonal
    egress_cost: Vec<f64>, // row-major n×n, cost units per demand unit
}

impl InterRegionMatrix {
    pub fn new(n: usize, latency_ms: Vec<f64>, egress_cost: Vec<f64>) -> Self {
        assert_eq!(latency_ms.len(), n * n, "latency shape");
        assert_eq!(egress_cost.len(), n * n, "egress shape");
        let mut m = Self { n, latency_ms, egress_cost };
        for i in 0..n {
            for j in (i + 1)..n {
                let avg = (m.latency_ms[i * n + j] + m.latency_ms[j * n + i]) / 2.0;
                m.latency_ms[i * n + j] = avg;
                m.latency_ms[j * n + i] = avg;
            }
            m.latency_ms[i * n + i] = 0.0;
            m.egress_cost[i * n + i] = 0.0;
        }
        m
    }

    /// Synthesize a geo-ring of global regions: neighbours sit ~30–60 ms
    /// apart, antipodes ~`n/2` hops away; egress cost grows with hop
    /// distance (same-continent transfers are cheap, cross-ocean is not).
    pub fn synthesize(n: usize, rng: &mut Pcg64) -> Self {
        assert!(n > 0, "need at least one region");
        let mut latency = vec![0.0; n * n];
        let mut egress = vec![0.0; n * n];
        let hop_ms: Vec<f64> = (0..n).map(|_| rng.uniform(30.0, 60.0)).collect();
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                // Ring distance in hops; latency = sum of hop lengths on
                // the shorter arc, so the triangle inequality holds.
                let d = (i as i64 - j as i64).unsigned_abs() as usize;
                let hops = d.min(n - d);
                let (lo, hi) = (i.min(j), i.max(j));
                let arc: f64 = if hi - lo == hops {
                    (lo..hi).map(|k| hop_ms[k]).sum()
                } else {
                    (hi..n).chain(0..lo).map(|k| hop_ms[k]).sum()
                };
                latency[i * n + j] = arc + 2.0;
                egress[i * n + j] = 0.01 * hops as f64;
            }
        }
        Self::new(n, latency, egress)
    }

    pub fn n_regions(&self) -> usize {
        self.n
    }

    pub fn latency_ms(&self, a: RegionId, b: RegionId) -> f64 {
        self.latency_ms[a.0 * self.n + b.0]
    }

    pub fn egress_cost(&self, a: RegionId, b: RegionId) -> f64 {
        self.egress_cost[a.0 * self.n + b.0]
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_regions", Json::num(self.n as f64)),
            ("latency_ms", Json::arr(self.latency_ms.iter().map(|&v| Json::num(v)))),
            ("egress_cost", Json::arr(self.egress_cost.iter().map(|&v| Json::num(v)))),
        ])
    }
}

/// The global layer's static map: which tiers each global region owns
/// (tier ids are region-local — every region runs its own SPTLB over its
/// own tier namespace) plus the inter-region cost matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionTopology {
    tier_sets: Vec<Vec<TierId>>,
    pub inter: InterRegionMatrix,
}

impl RegionTopology {
    pub fn new(tier_sets: Vec<Vec<TierId>>, inter: InterRegionMatrix) -> Self {
        assert_eq!(tier_sets.len(), inter.n_regions(), "topology shape");
        Self { tier_sets, inter }
    }

    pub fn n_regions(&self) -> usize {
        self.tier_sets.len()
    }

    /// Tiers (region-local ids) the region owns.
    pub fn tiers_of(&self, r: RegionId) -> &[TierId] {
        &self.tier_sets[r.idx()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_dedups_and_sorts() {
        let s = RegionSet::from_indices([3, 1, 3, 2]);
        assert_eq!(s.len(), 3);
        assert_eq!(
            s.as_slice(),
            &[RegionId(1), RegionId(2), RegionId(3)]
        );
    }

    #[test]
    fn contains_and_intersection() {
        let a = RegionSet::from_indices([0, 1, 2, 3]);
        let b = RegionSet::from_indices([2, 3, 4]);
        assert!(a.contains(RegionId(2)));
        assert!(!a.contains(RegionId(4)));
        assert_eq!(a.intersection_size(&b), 2);
        assert_eq!(b.intersection_size(&a), 2);
    }

    #[test]
    fn inter_region_matrix_is_symmetric_with_zero_diagonal() {
        let mut rng = Pcg64::new(3);
        let m = InterRegionMatrix::synthesize(5, &mut rng);
        for i in 0..5 {
            assert_eq!(m.latency_ms(RegionId(i), RegionId(i)), 0.0);
            assert_eq!(m.egress_cost(RegionId(i), RegionId(i)), 0.0);
            for j in 0..5 {
                assert_eq!(
                    m.latency_ms(RegionId(i), RegionId(j)),
                    m.latency_ms(RegionId(j), RegionId(i))
                );
                if i != j {
                    assert!(m.latency_ms(RegionId(i), RegionId(j)) > 0.0);
                    assert!(m.egress_cost(RegionId(i), RegionId(j)) > 0.0);
                }
            }
        }
    }

    #[test]
    fn inter_region_costs_grow_with_ring_distance() {
        let mut rng = Pcg64::new(9);
        let m = InterRegionMatrix::synthesize(6, &mut rng);
        // Antipodal (3 hops) must cost strictly more egress than adjacent.
        assert!(
            m.egress_cost(RegionId(0), RegionId(3)) > m.egress_cost(RegionId(0), RegionId(1))
        );
        assert!(
            m.latency_ms(RegionId(0), RegionId(3)) > m.latency_ms(RegionId(0), RegionId(1))
        );
    }

    #[test]
    fn inter_region_matrix_synthesis_is_deterministic() {
        let a = InterRegionMatrix::synthesize(4, &mut Pcg64::new(7));
        let b = InterRegionMatrix::synthesize(4, &mut Pcg64::new(7));
        assert_eq!(a, b);
        assert!(a.to_json().to_string().contains("latency_ms"));
    }

    #[test]
    fn topology_maps_regions_to_tier_sets() {
        let inter = InterRegionMatrix::synthesize(2, &mut Pcg64::new(1));
        let topo = RegionTopology::new(
            vec![vec![TierId(0), TierId(1)], vec![TierId(0)]],
            inter,
        );
        assert_eq!(topo.n_regions(), 2);
        assert_eq!(topo.tiers_of(RegionId(0)).len(), 2);
        assert_eq!(topo.tiers_of(RegionId(1)), &[TierId(0)]);
    }

    #[test]
    fn majority_overlap_is_strict() {
        let a = RegionSet::from_indices([0, 1]);
        let half = RegionSet::from_indices([0, 9]);
        assert!(!a.majority_overlap(&half), "exactly 50% must NOT pass");
        let most = RegionSet::from_indices([0, 1, 9]);
        assert!(a.majority_overlap(&most));
        assert!(!RegionSet::default().majority_overlap(&a));
    }
}
