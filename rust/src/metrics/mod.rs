//! Resource-metrics collection (§3.1). At Meta, each app exposes a live
//! monitoring endpoint; SPTLB scrapes cpu/mem/task-count timeseries and
//! keeps the *peak (99th percentile)* utilization to account for scaling
//! during execution. This module simulates those endpoints (stochastic
//! timeseries around a base demand) and implements the collector that
//! reduces series to p99 demand vectors plus tier limit metrics.

pub mod ingest;

pub use ingest::{IngestStats, ShedCounts, ShedReason};

use crate::metadata::{MetadataStore, MonitoringEndpoint};
use crate::model::{App, AppId, ResourceVec, Tier};
use crate::util::prng::Pcg64;
use crate::util::stats;
use std::collections::BTreeMap;

/// One scraped sample of an app's live resource usage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    /// Seconds since scrape start (simulated clock).
    pub at_secs: f64,
    pub usage: ResourceVec,
}

/// Source of live samples for an endpoint. Production: HTTP scrape.
/// Tests/benches: [`SimulatedMonitor`].
pub trait MetricSource {
    fn scrape(&mut self, endpoint: &MonitoringEndpoint, n_samples: usize) -> Vec<Sample>;

    /// Registration hook for simulated sources: called by the
    /// incremental collector when an app is new or its registered demand
    /// changed, before re-scraping it. Real scrape sources need no state
    /// and ignore it.
    fn observe_registration(&mut self, _app: &App) {}

    /// Forget a departed app (simulated sources drop its series base).
    fn forget(&mut self, _app: AppId) {}
}

/// Simulated monitoring endpoints. An app's registered demand is its
/// *peak* (what capacity planning cares about); live usage fluctuates
/// BELOW that peak with lognormal noise, normalized so the series' p99
/// lands on the registered demand (± sampling error). The collector's
/// p99 reduction therefore recovers the planning number from raw
/// samples — the same contract the paper's §3.1 collection stage has
/// with Meta's monitoring plane.
///
/// Each app's sample series is drawn from its own deterministic PRNG
/// stream (`Pcg64::stream(seed, app_id)`), so a scrape is a pure
/// function of (seed, app id, registered demand) — independent of which
/// *other* apps were scraped, or in what order. That independence is
/// what lets the incremental collector re-sample only event-touched apps
/// while staying bit-identical to a full re-collection.
#[derive(Debug)]
pub struct SimulatedMonitor {
    base: BTreeMap<AppId, ResourceVec>,
    seed: u64,
    /// Relative noise sigma for the lognormal multiplier.
    pub noise_sigma: f64,
}

/// z-score of the 99th percentile of a standard normal.
const Z99: f64 = 2.326;

impl SimulatedMonitor {
    pub fn new(apps: &[App], seed: u64) -> Self {
        Self {
            base: apps.iter().map(|a| (a.id, a.demand)).collect(),
            seed,
            noise_sigma: 0.15,
        }
    }

    /// A monitor with no registered apps yet; the incremental collector
    /// registers them through [`MetricSource::observe_registration`].
    pub fn empty(seed: u64) -> Self {
        Self { base: BTreeMap::new(), seed, noise_sigma: 0.15 }
    }
}

impl MetricSource for SimulatedMonitor {
    fn scrape(&mut self, endpoint: &MonitoringEndpoint, n_samples: usize) -> Vec<Sample> {
        let base = *self
            .base
            .get(&endpoint.app)
            .unwrap_or(&ResourceVec::ZERO);
        let mut rng = Pcg64::stream(self.seed, endpoint.app.0 as u64);
        // Normalize the lognormal so its p99 is 1.0 (i.e. the peak).
        let p99_mult = (Z99 * self.noise_sigma).exp();
        (0..n_samples)
            .map(|i| {
                let mult = rng.log_normal(0.0, self.noise_sigma) / p99_mult;
                let mut usage = base.scale(mult);
                // Task count is integral and changes rarely: round and keep
                // within a few % of the registered value.
                let t = base.tasks() * rng.uniform(0.97, 1.0);
                usage.0[2] = t.round().max(0.0);
                Sample { at_secs: i as f64, usage }
            })
            .collect()
    }

    fn observe_registration(&mut self, app: &App) {
        self.base.insert(app.id, app.demand);
    }

    fn forget(&mut self, app: AppId) {
        self.base.remove(&app);
    }
}

/// p99 demand per app after collection (what the solver consumes).
#[derive(Debug, Clone, PartialEq)]
pub struct CollectedApp {
    pub id: AppId,
    /// Peak (p99) observed usage per resource (§3.1).
    pub p99_demand: ResourceVec,
    pub n_samples: usize,
}

/// Per-tier limit metrics (§3.1: "tier metrics in terms of their limits
/// and ideal resource utilization conditions").
#[derive(Debug, Clone, PartialEq)]
pub struct TierMetrics {
    pub capacity: ResourceVec,
    pub ideal_utilization: ResourceVec,
}

/// Collector output: everything §3.2's problem construction needs.
#[derive(Debug, Clone)]
pub struct CollectionReport {
    pub apps: Vec<CollectedApp>,
    pub tiers: Vec<TierMetrics>,
}

/// Scrape every running app and reduce to p99 demand vectors.
pub struct Collector<'a, S: MetricSource> {
    store: &'a MetadataStore,
    source: S,
    /// Samples scraped per app (default 200 — enough for a stable p99).
    pub samples_per_app: usize,
}

impl<'a, S: MetricSource> Collector<'a, S> {
    pub fn new(store: &'a MetadataStore, source: S) -> Self {
        Self { store, source, samples_per_app: 200 }
    }

    pub fn collect(&mut self, tiers: &[Tier]) -> CollectionReport {
        let mut apps = Vec::with_capacity(self.store.len());
        for app in self.store.running_apps() {
            let ep = self
                .store
                .monitoring_endpoint(app.id)
                .expect("app registered but endpoint missing");
            let samples = self.source.scrape(&ep, self.samples_per_app);
            apps.push(CollectedApp {
                id: app.id,
                p99_demand: reduce_p99(&samples),
                n_samples: samples.len(),
            });
        }
        let tiers = tiers
            .iter()
            .map(|t| TierMetrics {
                capacity: t.capacity,
                ideal_utilization: t.ideal_utilization,
            })
            .collect();
        CollectionReport { apps, tiers }
    }
}

/// One cached collection result, keyed by the registered demand it was
/// scraped under.
#[derive(Debug, Clone)]
struct CachedCollection {
    registered: ResourceVec,
    collected: CollectedApp,
}

/// Event-driven collector: re-scrapes *only* apps whose registered
/// demand changed since the last round (drift events) or that are new
/// (arrivals), serving everything else from cache; departed apps are
/// evicted. Because a [`SimulatedMonitor`] scrape is a pure function of
/// (seed, app id, registered demand), the cached values are bit-identical
/// to what a full re-collection would produce — the engine's
/// incremental-vs-rebuild equivalence depends on exactly that.
pub struct IncrementalCollector<S: MetricSource> {
    source: S,
    /// Samples scraped per (dirty) app.
    pub samples_per_app: usize,
    cache: BTreeMap<AppId, CachedCollection>,
}

impl<S: MetricSource> IncrementalCollector<S> {
    pub fn new(source: S, samples_per_app: usize) -> Self {
        // No clamping: the count must match `Collector` exactly, or the
        // incremental and rebuild engines diverge on degenerate configs.
        Self { source, samples_per_app, cache: BTreeMap::new() }
    }

    pub fn source_mut(&mut self) -> &mut S {
        &mut self.source
    }

    /// Collect p99 demands for `apps` (the fleet in ascending-id order),
    /// scraping only dirty apps. Returns the collected apps positionally
    /// parallel to `apps`, plus how many endpoints were actually scraped
    /// (the incrementality win the coordinator bench measures).
    pub fn collect(&mut self, store: &MetadataStore, apps: &[App]) -> (Vec<CollectedApp>, usize) {
        // Evict departed apps first so the cache never outlives the fleet.
        let departed: Vec<AppId> = {
            let mut live = apps.iter().map(|a| a.id).peekable();
            let mut gone = Vec::new();
            for &id in self.cache.keys() {
                while live.peek().is_some_and(|l| *l < id) {
                    live.next();
                }
                if live.peek() != Some(&id) {
                    gone.push(id);
                }
            }
            gone
        };
        for id in departed {
            self.cache.remove(&id);
            self.source.forget(id);
        }

        let mut out = Vec::with_capacity(apps.len());
        let mut scraped = 0usize;
        for app in apps {
            match self.cache.get(&app.id) {
                Some(c) if c.registered == app.demand => out.push(c.collected.clone()),
                _ => {
                    self.source.observe_registration(app);
                    let ep = store
                        .monitoring_endpoint(app.id)
                        .expect("fleet app registered but endpoint missing");
                    let samples = self.source.scrape(&ep, self.samples_per_app);
                    scraped += 1;
                    let collected = CollectedApp {
                        id: app.id,
                        p99_demand: reduce_p99(&samples),
                        n_samples: samples.len(),
                    };
                    self.cache.insert(
                        app.id,
                        CachedCollection { registered: app.demand, collected: collected.clone() },
                    );
                    out.push(collected);
                }
            }
        }
        (out, scraped)
    }
}

/// Reduce a scraped series to its per-resource p99.
pub fn reduce_p99(samples: &[Sample]) -> ResourceVec {
    if samples.is_empty() {
        return ResourceVec::ZERO;
    }
    let mut out = ResourceVec::ZERO;
    for r in 0..crate::model::NUM_RESOURCES {
        let series: Vec<f64> = samples.iter().map(|s| s.usage.0[r]).collect();
        out.0[r] = stats::p99(&series);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Criticality, RegionId, RegionSet, Slo, TierId};
    use crate::model::tier::default_ideal_utilization;

    fn mk_store(n: usize) -> MetadataStore {
        MetadataStore::from_apps((0..n).map(|i| App {
            id: AppId::from_usize(i),
            name: format!("app{i}"),
            demand: ResourceVec::new(10.0, 20.0, 100.0),
            slo: Slo::Slo3,
            criticality: Criticality::new(0.5),
            preferred_region: RegionId(0),
        }))
        .unwrap()
    }

    fn mk_tiers() -> Vec<Tier> {
        vec![Tier {
            id: TierId(0),
            name: "tier1".into(),
            capacity: ResourceVec::new(1000.0, 1000.0, 1000.0),
            ideal_utilization: default_ideal_utilization(),
            supported_slos: vec![Slo::Slo3],
            regions: RegionSet::from_indices([0]),
        }]
    }

    #[test]
    fn p99_reduction_on_constant_series() {
        let samples: Vec<Sample> = (0..100)
            .map(|i| Sample { at_secs: i as f64, usage: ResourceVec::new(5.0, 6.0, 7.0) })
            .collect();
        assert_eq!(reduce_p99(&samples), ResourceVec::new(5.0, 6.0, 7.0));
    }

    #[test]
    fn collected_p99_recovers_registered_peak() {
        let store = mk_store(1);
        let mut collector = Collector::new(&store, SimulatedMonitor::new(&store.running_apps(), 1));
        collector.samples_per_app = 2000;
        let report = collector.collect(&mk_tiers());
        let p99 = report.apps[0].p99_demand;
        // The series is normalized so p99 ~= the registered peak (10/20/100).
        assert!((p99.cpu() - 10.0).abs() < 1.0, "p99 cpu {}", p99.cpu());
        assert!((p99.mem() - 20.0).abs() < 2.0, "p99 mem {}", p99.mem());
        assert!((p99.tasks() - 100.0).abs() <= 5.0);
    }

    #[test]
    fn mean_usage_is_below_peak() {
        let store = mk_store(1);
        let mut mon = SimulatedMonitor::new(&store.running_apps(), 2);
        let ep = store.monitoring_endpoint(crate::model::AppId(0)).unwrap();
        let samples = mon.scrape(&ep, 1000);
        let mean_cpu: f64 =
            samples.iter().map(|s| s.usage.cpu()).sum::<f64>() / samples.len() as f64;
        assert!(mean_cpu < 10.0 * 0.85, "mean {mean_cpu} well below peak 10");
    }

    #[test]
    fn collect_covers_all_apps_and_tiers() {
        let store = mk_store(5);
        let mut collector = Collector::new(&store, SimulatedMonitor::new(&store.running_apps(), 2));
        let report = collector.collect(&mk_tiers());
        assert_eq!(report.apps.len(), 5);
        assert_eq!(report.tiers.len(), 1);
        assert_eq!(report.tiers[0].ideal_utilization, default_ideal_utilization());
        assert!(report.apps.iter().all(|a| a.n_samples == 200));
    }

    #[test]
    fn deterministic_given_seed() {
        let store = mk_store(3);
        let run = |seed| {
            let mut c = Collector::new(&store, SimulatedMonitor::new(&store.running_apps(), seed));
            c.collect(&mk_tiers())
                .apps
                .iter()
                .map(|a| a.p99_demand)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn empty_series_reduces_to_zero() {
        assert_eq!(reduce_p99(&[]), ResourceVec::ZERO);
    }

    #[test]
    fn scrape_is_independent_of_other_apps() {
        // Per-app PRNG streams: app 1's series must not depend on whether
        // (or how often) other apps were scraped — the property that
        // makes cached collection bit-identical to full re-collection.
        let store = mk_store(3);
        let apps = store.running_apps();
        let ep1 = store.monitoring_endpoint(AppId(1)).unwrap();
        let mut a = SimulatedMonitor::new(&apps, 5);
        let solo = a.scrape(&ep1, 50);
        let mut b = SimulatedMonitor::new(&apps, 5);
        for id in [0usize, 2, 0] {
            let ep = store.monitoring_endpoint(AppId::from_usize(id)).unwrap();
            let _ = b.scrape(&ep, 50);
        }
        assert_eq!(b.scrape(&ep1, 50), solo);
    }

    #[test]
    fn incremental_collection_matches_full_collection() {
        let store = mk_store(4);
        let apps = store.running_apps();
        let seed = 11;
        let full = {
            let mut c = Collector::new(&store, SimulatedMonitor::new(&apps, seed));
            c.collect(&mk_tiers()).apps
        };
        let mut inc = IncrementalCollector::new(SimulatedMonitor::empty(seed), 200);
        let (first, scraped_first) = inc.collect(&store, &apps);
        assert_eq!(scraped_first, 4, "everything is dirty on first contact");
        assert_eq!(first, full, "incremental must equal full collection");
        // Second round, nothing drifted: all served from cache.
        let (second, scraped_second) = inc.collect(&store, &apps);
        assert_eq!(scraped_second, 0);
        assert_eq!(second, full);
    }

    #[test]
    fn incremental_collection_rescrapes_only_drifted_apps() {
        let store = mk_store(4);
        let mut apps = store.running_apps();
        let seed = 11;
        let mut inc = IncrementalCollector::new(SimulatedMonitor::empty(seed), 200);
        let _ = inc.collect(&store, &apps);
        // Drift one app's registered demand; only it gets re-scraped,
        // and the result equals a from-scratch full collection over the
        // drifted fleet.
        apps[2].demand = apps[2].demand.scale(1.7);
        let drifted_store = MetadataStore::from_apps(apps.clone()).unwrap();
        let (inc_result, scraped) = inc.collect(&drifted_store, &apps);
        assert_eq!(scraped, 1, "only the drifted app is re-scraped");
        let full = {
            let mut c = Collector::new(&drifted_store, SimulatedMonitor::new(&apps, seed));
            c.collect(&mk_tiers()).apps
        };
        assert_eq!(inc_result, full);
    }

    #[test]
    fn incremental_collection_evicts_departed_and_adds_arrivals() {
        let store = mk_store(4);
        let apps = store.running_apps();
        let seed = 3;
        let mut inc = IncrementalCollector::new(SimulatedMonitor::empty(seed), 100);
        let _ = inc.collect(&store, &apps);
        // App 1 departs; app 7 arrives.
        let mut next: Vec<App> = apps.iter().filter(|a| a.id != AppId(1)).cloned().collect();
        next.push(App { id: AppId(7), name: "app7".into(), ..apps[0].clone() });
        let next_store = MetadataStore::from_apps(next.clone()).unwrap();
        let (got, scraped) = inc.collect(&next_store, &next);
        assert_eq!(scraped, 1, "only the arrival is scraped");
        let full = {
            let mut c = Collector::new(&next_store, SimulatedMonitor::new(&next, seed));
            c.collect(&mk_tiers()).apps
        };
        assert_eq!(got, full);
    }
}
