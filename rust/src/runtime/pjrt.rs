//! The real PJRT-backed scorer (requires the vendored `xla` bindings;
//! enabled by the `pjrt` cargo feature). See the module docs on
//! [`crate::runtime`] for the artifact contract.

use super::{problem_fingerprint, ArtifactVariant, Manifest};
use crate::model::{Assignment, NUM_RESOURCES};
use crate::rebalancer::problem::Problem;
use crate::rebalancer::BatchScorer;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// A compiled scoring executable for one artifact variant.
struct CompiledVariant {
    spec: ArtifactVariant,
    exe: xla::PjRtLoadedExecutable,
}

/// Problem-side input literals, cached across `score` calls (§Perf: the
/// LocalSearch hot loop scores hundreds of neighborhoods against the SAME
/// problem; rebuilding six literals per dispatch wasted ~20% of the
/// device-path time).
struct CachedProblem {
    fingerprint: u64,
    a_pad: usize,
    res: xla::Literal,
    cap: xla::Literal,
    ideal: xla::Literal,
    init: xla::Literal,
    crit: xla::Literal,
    weights: xla::Literal,
}

/// PJRT-backed batch scorer. Compiles lazily per (tiers, apps) shape and
/// caches the executable for the process lifetime.
pub struct PjrtScorer {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: Vec<CompiledVariant>,
    cached: Option<CachedProblem>,
    /// Total PJRT dispatches (perf accounting).
    pub dispatches: u64,
    /// Total candidates scored through the device path.
    pub scored: u64,
}

impl PjrtScorer {
    /// Create from an artifact directory (default: `artifacts/`).
    pub fn from_dir(dir: &Path) -> Result<PjrtScorer> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(PjrtScorer {
            client,
            manifest,
            compiled: Vec::new(),
            cached: None,
            dispatches: 0,
            scored: 0,
        })
    }

    pub fn from_default_dir() -> Result<PjrtScorer> {
        Self::from_dir(Path::new("artifacts"))
    }

    fn ensure_compiled(&mut self, n_apps: usize, n_tiers: usize) -> Result<usize> {
        if let Some(i) = self
            .compiled
            .iter()
            .position(|c| c.spec.tiers == n_tiers && c.spec.apps >= n_apps)
        {
            return Ok(i);
        }
        let spec = self
            .manifest
            .pick(n_apps, n_tiers)
            .ok_or_else(|| {
                anyhow!("no artifact variant fits A={n_apps} T={n_tiers}; re-run aot.py with --variants")
            })?
            .clone();
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("loading {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("XLA compile")?;
        log::info!(
            "compiled artifact {} (A={} T={} B={})",
            spec.name,
            spec.apps,
            spec.tiers,
            spec.batch
        );
        self.compiled.push(CompiledVariant { spec, exe });
        Ok(self.compiled.len() - 1)
    }

    /// Score candidates through the device artifact. Returns one f64
    /// score per candidate (f32 on device; semantics of `ref.py`).
    pub fn score(&mut self, problem: &Problem, candidates: &[Assignment]) -> Result<Vec<f64>> {
        if candidates.is_empty() {
            return Ok(Vec::new());
        }
        let n_apps = problem.n_apps();
        let n_tiers = problem.n_tiers();
        let ci = self.ensure_compiled(n_apps, n_tiers)?;
        let (a_pad, b_cap) = {
            let spec = &self.compiled[ci].spec;
            (spec.apps, spec.batch)
        };

        // Problem-side tensors: cached across calls for the same problem.
        let fp = problem_fingerprint(problem);
        let cache_ok = matches!(&self.cached, Some(c) if c.fingerprint == fp && c.a_pad == a_pad);
        if !cache_ok {
            let res = self.res_literal(problem, a_pad)?;
            let cap = self.tier_matrix_literal(problem, n_tiers, |t| t.capacity.as_f32())?;
            let ideal =
                self.tier_matrix_literal(problem, n_tiers, |t| t.ideal_utilization.as_f32())?;
            let init = self.onehot_literal(problem.initial.as_slice(), a_pad, n_tiers)?;
            let crit = {
                let mut v = vec![0f32; a_pad];
                for (i, app) in problem.apps.iter().enumerate() {
                    v[i] = app.criticality as f32;
                }
                xla::Literal::vec1(&v).reshape(&[a_pad as i64])?
            };
            let weights = {
                let w64 = problem.weights.as_array();
                let w: Vec<f32> = w64.iter().map(|&x| x as f32).collect();
                xla::Literal::vec1(&w).reshape(&[w.len() as i64])?
            };
            self.cached =
                Some(CachedProblem { fingerprint: fp, a_pad, res, cap, ideal, init, crit, weights });
        }

        let mut out = Vec::with_capacity(candidates.len());
        for chunk in candidates.chunks(b_cap) {
            // Pad the chunk to B by replicating the last candidate
            // (padding rows are discarded below).
            let mut assign = vec![0f32; b_cap * a_pad * n_tiers];
            for b in 0..b_cap {
                let cand = chunk.get(b).unwrap_or(chunk.last().unwrap());
                debug_assert_eq!(cand.n_apps(), n_apps);
                let base = b * a_pad * n_tiers;
                for (i, t) in cand.as_slice().iter().enumerate() {
                    assign[base + i * n_tiers + t.idx()] = 1.0;
                }
                // Padding apps: pinned to tier 0 in both init and cand.
                for i in n_apps..a_pad {
                    assign[base + i * n_tiers] = 1.0;
                }
            }
            let assign = xla::Literal::vec1(&assign).reshape(&[
                b_cap as i64,
                a_pad as i64,
                n_tiers as i64,
            ])?;

            let c = self.cached.as_ref().expect("cache populated above");
            let result = self.compiled[ci]
                .exe
                .execute::<xla::Literal>(&[
                    assign,
                    c.res.clone(),
                    c.cap.clone(),
                    c.ideal.clone(),
                    c.init.clone(),
                    c.crit.clone(),
                    c.weights.clone(),
                ])
                .context("PJRT execute")?[0][0]
                .to_literal_sync()?;
            let outputs = result.to_tuple()?;
            let scores = outputs[0].to_vec::<f32>()?;
            self.dispatches += 1;
            self.scored += chunk.len() as u64;
            out.extend(scores[..chunk.len()].iter().map(|&s| s as f64));
        }
        Ok(out)
    }

    fn res_literal(&self, problem: &Problem, a_pad: usize) -> Result<xla::Literal> {
        let mut v = vec![0f32; a_pad * NUM_RESOURCES];
        for (i, app) in problem.apps.iter().enumerate() {
            let d = app.demand.as_f32();
            v[i * NUM_RESOURCES..(i + 1) * NUM_RESOURCES].copy_from_slice(&d);
        }
        Ok(xla::Literal::vec1(&v).reshape(&[a_pad as i64, NUM_RESOURCES as i64])?)
    }

    fn tier_matrix_literal(
        &self,
        problem: &Problem,
        n_tiers: usize,
        f: impl Fn(&crate::rebalancer::problem::ProblemTier) -> [f32; NUM_RESOURCES],
    ) -> Result<xla::Literal> {
        let mut v = vec![0f32; n_tiers * NUM_RESOURCES];
        for (t, tier) in problem.tiers.iter().enumerate() {
            v[t * NUM_RESOURCES..(t + 1) * NUM_RESOURCES].copy_from_slice(&f(tier));
        }
        Ok(xla::Literal::vec1(&v).reshape(&[n_tiers as i64, NUM_RESOURCES as i64])?)
    }

    fn onehot_literal(
        &self,
        tiers: &[crate::model::TierId],
        a_pad: usize,
        n_tiers: usize,
    ) -> Result<xla::Literal> {
        let mut v = vec![0f32; a_pad * n_tiers];
        for (i, t) in tiers.iter().enumerate() {
            v[i * n_tiers + t.idx()] = 1.0;
        }
        for i in tiers.len()..a_pad {
            v[i * n_tiers] = 1.0; // padding apps on tier 0
        }
        Ok(xla::Literal::vec1(&v).reshape(&[a_pad as i64, n_tiers as i64])?)
    }
}

impl BatchScorer for PjrtScorer {
    fn score_batch(
        &mut self,
        problem: &Problem,
        candidates: &[Assignment],
    ) -> Result<Vec<f64>> {
        self.score(problem, candidates)
    }
}
