//! Dense two-phase primal simplex, from scratch (no LP crate offline).
//! Supports `<=`, `>=`, and `=` rows over non-negative variables —
//! exactly what OptimalSearch's relaxation (see `optimal.rs`) needs.
//!
//! Implementation notes:
//!  * Phase 1 minimizes the sum of artificial variables; phase 2 proceeds
//!    only if phase 1 reaches ~0.
//!  * Dantzig pricing with a Bland's-rule fallback after a degeneracy
//!    streak prevents cycling.
//!  * Dense row-major tableau: fine at our scale (hundreds × hundreds).

/// Row sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sense {
    Le,
    Ge,
    Eq,
}

/// One linear constraint: `coeffs · x  (sense)  rhs`.
#[derive(Debug, Clone)]
pub struct Row {
    /// Sparse (var, coeff) pairs.
    pub coeffs: Vec<(usize, f64)>,
    pub sense: Sense,
    pub rhs: f64,
}

/// LP: minimize `objective · x` subject to rows, `x >= 0`.
#[derive(Debug, Clone, Default)]
pub struct Lp {
    pub n_vars: usize,
    /// Sparse objective (var, coeff); minimization.
    pub objective: Vec<(usize, f64)>,
    pub rows: Vec<Row>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum LpOutcome {
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
    Unbounded,
    /// Iteration limit hit; x is the best feasible point found (phase-2
    /// iterate) if any.
    IterationLimit,
    /// Wall-clock deadline expired before the pivot budget ran out. Kept
    /// distinct from [`LpOutcome::IterationLimit`] (and from
    /// `Infeasible`) so anytime callers can tell "out of time" apart from
    /// "proved infeasible" / "pivot budget exhausted".
    DeadlineExpired,
}

impl Lp {
    pub fn new(n_vars: usize) -> Self {
        Self { n_vars, objective: Vec::new(), rows: Vec::new() }
    }

    pub fn set_objective(&mut self, var: usize, coeff: f64) {
        debug_assert!(var < self.n_vars);
        self.objective.push((var, coeff));
    }

    pub fn add_row(&mut self, coeffs: Vec<(usize, f64)>, sense: Sense, rhs: f64) {
        debug_assert!(coeffs.iter().all(|&(v, _)| v < self.n_vars));
        self.rows.push(Row { coeffs, sense, rhs });
    }

    /// Solve; `max_iters` bounds total pivots across both phases.
    pub fn solve(&self, max_iters: usize) -> LpOutcome {
        Tableau::build(self).solve(max_iters, None)
    }

    /// Solve with a wall-clock deadline (checked every few pivots); on
    /// expiry returns [`LpOutcome::DeadlineExpired`]. With an unexpired
    /// (e.g. [`crate::util::timer::Deadline::unbounded`]) deadline this
    /// returns exactly what [`Lp::solve`] returns for the same instance
    /// and pivot budget — both paths share one tableau implementation.
    pub fn solve_with_deadline(
        &self,
        max_iters: usize,
        deadline: crate::util::timer::Deadline,
    ) -> LpOutcome {
        Tableau::build(self).solve(max_iters, Some(deadline))
    }
}

const EPS: f64 = 1e-9;

struct Tableau {
    /// rows × cols coefficient matrix (col-slack/artificial augmented).
    a: Vec<f64>,
    b: Vec<f64>,
    n_rows: usize,
    n_cols: usize,
    n_structural: usize,
    /// Basis: column index per row.
    basis: Vec<usize>,
    /// Phase-2 cost per column.
    cost: Vec<f64>,
    /// First artificial column (columns >= this are artificial).
    art_start: usize,
}

impl Tableau {
    fn build(lp: &Lp) -> Tableau {
        let n_rows = lp.rows.len();
        // Count slacks (one per inequality) and artificials (Ge/Eq rows).
        let n_slack = lp.rows.iter().filter(|r| r.sense != Sense::Eq).count();
        let n_art = lp
            .rows
            .iter()
            .filter(|r| {
                // After rhs normalization a Ge row needs an artificial; an
                // Le row with negative rhs flips to Ge-like. Compute below.
                let rhs_neg = r.rhs < 0.0;
                match (r.sense, rhs_neg) {
                    (Sense::Eq, _) => true,
                    (Sense::Ge, false) => true,
                    (Sense::Le, true) => true,
                    _ => false,
                }
            })
            .count();
        let n_structural = lp.n_vars;
        let n_cols = n_structural + n_slack + n_art;
        let art_start = n_structural + n_slack;

        let mut a = vec![0.0; n_rows * n_cols];
        let mut b = vec![0.0; n_rows];
        let mut basis = vec![usize::MAX; n_rows];
        let mut slack_i = 0;
        let mut art_i = 0;

        for (i, row) in lp.rows.iter().enumerate() {
            // Normalize to rhs >= 0 (flip the row if needed).
            let flip = row.rhs < 0.0;
            let sgn = if flip { -1.0 } else { 1.0 };
            for &(v, c) in &row.coeffs {
                a[i * n_cols + v] += sgn * c;
            }
            b[i] = sgn * row.rhs;
            let eff_sense = match (row.sense, flip) {
                (Sense::Eq, _) => Sense::Eq,
                (Sense::Le, false) | (Sense::Ge, true) => Sense::Le,
                (Sense::Ge, false) | (Sense::Le, true) => Sense::Ge,
            };
            match eff_sense {
                Sense::Le => {
                    let col = n_structural + slack_i;
                    a[i * n_cols + col] = 1.0;
                    basis[i] = col;
                    slack_i += 1;
                }
                Sense::Ge => {
                    let scol = n_structural + slack_i;
                    a[i * n_cols + scol] = -1.0; // surplus
                    slack_i += 1;
                    let acol = art_start + art_i;
                    a[i * n_cols + acol] = 1.0;
                    basis[i] = acol;
                    art_i += 1;
                }
                Sense::Eq => {
                    let acol = art_start + art_i;
                    a[i * n_cols + acol] = 1.0;
                    basis[i] = acol;
                    art_i += 1;
                }
            }
        }
        debug_assert!(basis.iter().all(|&c| c != usize::MAX));

        let mut cost = vec![0.0; n_cols];
        for &(v, c) in &lp.objective {
            cost[v] += c;
        }

        Tableau { a, b, n_rows, n_cols, n_structural, basis, cost, art_start }
    }

    #[inline]
    fn at(&self, r: usize, c: usize) -> f64 {
        self.a[r * self.n_cols + c]
    }

    fn pivot(&mut self, pr: usize, pc: usize) {
        let n_cols = self.n_cols;
        let pivot_val = self.a[pr * n_cols + pc];
        debug_assert!(pivot_val.abs() > EPS);
        let inv = 1.0 / pivot_val;
        for c in 0..n_cols {
            self.a[pr * n_cols + c] *= inv;
        }
        self.b[pr] *= inv;
        for r in 0..self.n_rows {
            if r == pr {
                continue;
            }
            let factor = self.a[r * n_cols + pc];
            if factor.abs() <= EPS {
                continue;
            }
            for c in 0..n_cols {
                self.a[r * n_cols + c] -= factor * self.a[pr * n_cols + c];
            }
            self.b[r] -= factor * self.b[pr];
            if self.b[r].abs() < 1e-12 {
                self.b[r] = 0.0;
            }
        }
        self.basis[pr] = pc;
    }

    /// Reduced costs for the given cost vector under the current basis.
    fn reduced_costs(&self, cost: &[f64]) -> Vec<f64> {
        // y = c_B B^-1 is implicit: since the tableau is kept in canonical
        // form, reduced cost_j = c_j - Σ_r c_basis[r] * a[r][j].
        let mut rc = cost.to_vec();
        for r in 0..self.n_rows {
            let cb = cost[self.basis[r]];
            if cb == 0.0 {
                continue;
            }
            for c in 0..self.n_cols {
                rc[c] -= cb * self.at(r, c);
            }
        }
        rc
    }

    fn objective_value(&self, cost: &[f64]) -> f64 {
        (0..self.n_rows).map(|r| cost[self.basis[r]] * self.b[r]).sum()
    }

    /// Run simplex for `cost`, restricted to columns < `col_limit`.
    /// Returns Ok(iterations_used) or Err(Unbounded).
    fn run(
        &mut self,
        cost: &[f64],
        col_limit: usize,
        max_iters: usize,
        deadline: Option<crate::util::timer::Deadline>,
    ) -> Result<usize, LpOutcome> {
        let mut degenerate_streak = 0usize;
        for iter in 0..max_iters {
            if iter % 8 == 0 {
                if let Some(d) = deadline {
                    if d.expired() {
                        return Err(LpOutcome::DeadlineExpired);
                    }
                }
            }
            let rc = self.reduced_costs(cost);
            // Entering column: Dantzig; Bland after a degeneracy streak.
            // NaN-safe pricing: `total_cmp` never panics (degenerate goal
            // weights can produce non-finite reduced costs) and the index
            // tiebreak keeps pivot choice bit-stable across platforms.
            let entering = if degenerate_streak > 24 {
                (0..col_limit).find(|&c| rc[c] < -EPS)
            } else {
                (0..col_limit)
                    .filter(|&c| rc[c] < -EPS)
                    .min_by(|&x, &y| rc[x].total_cmp(&rc[y]).then(x.cmp(&y)))
            };
            let Some(pc) = entering else {
                return Ok(iter);
            };
            // Ratio test.
            let mut pr: Option<usize> = None;
            let mut best_ratio = f64::INFINITY;
            for r in 0..self.n_rows {
                let arc = self.at(r, pc);
                if arc > EPS {
                    let ratio = self.b[r] / arc;
                    if ratio < best_ratio - EPS
                        || (ratio < best_ratio + EPS
                            && pr.map_or(true, |p| self.basis[r] < self.basis[p]))
                    {
                        best_ratio = ratio;
                        pr = Some(r);
                    }
                }
            }
            let Some(pr) = pr else {
                return Err(LpOutcome::Unbounded);
            };
            if best_ratio < EPS {
                degenerate_streak += 1;
            } else {
                degenerate_streak = 0;
            }
            self.pivot(pr, pc);
        }
        Err(LpOutcome::IterationLimit)
    }

    fn extract_x(&self) -> Vec<f64> {
        let mut x = vec![0.0; self.n_structural];
        for r in 0..self.n_rows {
            let c = self.basis[r];
            if c < self.n_structural {
                x[c] = self.b[r].max(0.0);
            }
        }
        x
    }

    fn solve(mut self, max_iters: usize, deadline: Option<crate::util::timer::Deadline>) -> LpOutcome {
        // ---- phase 1: drive artificials out.
        let has_artificials = self.art_start < self.n_cols;
        let mut used = 0usize;
        if has_artificials {
            let mut phase1_cost = vec![0.0; self.n_cols];
            for c in self.art_start..self.n_cols {
                phase1_cost[c] = 1.0;
            }
            match self.run(&phase1_cost, self.n_cols, max_iters, deadline) {
                Ok(it) => used = it,
                Err(LpOutcome::Unbounded) => return LpOutcome::Infeasible,
                Err(other) => return other,
            }
            if self.objective_value(&phase1_cost) > 1e-6 {
                return LpOutcome::Infeasible;
            }
            // Pivot out any artificial still (degenerately) in the basis.
            for r in 0..self.n_rows {
                if self.basis[r] >= self.art_start {
                    if let Some(pc) =
                        (0..self.art_start).find(|&c| self.at(r, c).abs() > EPS)
                    {
                        self.pivot(r, pc);
                    }
                }
            }
        }
        // ---- phase 2: optimize the real objective over non-artificials.
        // Take, don't clone: `run` needs `&mut self` while pricing against
        // the phase-2 cost, and `solve` owns `self` outright.
        let cost = std::mem::take(&mut self.cost);
        let budget = max_iters.saturating_sub(used).max(1);
        match self.run(&cost, self.art_start, budget, deadline) {
            Ok(_) => {
                let x = self.extract_x();
                let objective = self.objective_value(&cost);
                LpOutcome::Optimal { x, objective }
            }
            Err(LpOutcome::Unbounded) => LpOutcome::Unbounded,
            // Preserve the deadline/pivot-budget distinction: phase 2 used
            // to collapse every error into IterationLimit, which made a
            // hit deadline indistinguishable from an exhausted budget.
            Err(other) => other,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(outcome: LpOutcome, want_obj: f64, want_x: Option<&[f64]>) {
        match outcome {
            LpOutcome::Optimal { x, objective } => {
                assert!(
                    (objective - want_obj).abs() < 1e-6,
                    "objective {objective} want {want_obj}"
                );
                if let Some(wx) = want_x {
                    for (got, want) in x.iter().zip(wx) {
                        assert!((got - want).abs() < 1e-6, "x {x:?} want {wx:?}");
                    }
                }
            }
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn basic_le_maximization_as_min() {
        // max x+y s.t. x<=2, y<=3  -> min -(x+y) = -5.
        let mut lp = Lp::new(2);
        lp.set_objective(0, -1.0);
        lp.set_objective(1, -1.0);
        lp.add_row(vec![(0, 1.0)], Sense::Le, 2.0);
        lp.add_row(vec![(1, 1.0)], Sense::Le, 3.0);
        assert_opt(lp.solve(100), -5.0, Some(&[2.0, 3.0]));
    }

    #[test]
    fn equality_constraints() {
        // min x+2y s.t. x+y = 4, x <= 1  -> x=1, y=3, obj 7.
        let mut lp = Lp::new(2);
        lp.set_objective(0, 1.0);
        lp.set_objective(1, 2.0);
        lp.add_row(vec![(0, 1.0), (1, 1.0)], Sense::Eq, 4.0);
        lp.add_row(vec![(0, 1.0)], Sense::Le, 1.0);
        assert_opt(lp.solve(100), 7.0, Some(&[1.0, 3.0]));
    }

    #[test]
    fn ge_constraints() {
        // min 2x+3y s.t. x+y >= 10, x <= 6 -> x=6,y=4, obj 24.
        let mut lp = Lp::new(2);
        lp.set_objective(0, 2.0);
        lp.set_objective(1, 3.0);
        lp.add_row(vec![(0, 1.0), (1, 1.0)], Sense::Ge, 10.0);
        lp.add_row(vec![(0, 1.0)], Sense::Le, 6.0);
        assert_opt(lp.solve(100), 24.0, Some(&[6.0, 4.0]));
    }

    #[test]
    fn infeasible_detected() {
        // x <= 1 and x >= 2.
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add_row(vec![(0, 1.0)], Sense::Le, 1.0);
        lp.add_row(vec![(0, 1.0)], Sense::Ge, 2.0);
        assert_eq!(lp.solve(100), LpOutcome::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        // min -x, x >= 0 unconstrained above.
        let mut lp = Lp::new(1);
        lp.set_objective(0, -1.0);
        lp.add_row(vec![(0, 1.0)], Sense::Ge, 0.0);
        assert_eq!(lp.solve(100), LpOutcome::Unbounded);
    }

    #[test]
    fn negative_rhs_normalized() {
        // -x <= -2  ===  x >= 2; min x -> 2.
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add_row(vec![(0, -1.0)], Sense::Le, -2.0);
        assert_opt(lp.solve(100), 2.0, Some(&[2.0]));
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Classic degenerate LP; Bland fallback must terminate.
        let mut lp = Lp::new(4);
        lp.set_objective(0, -0.75);
        lp.set_objective(1, 150.0);
        lp.set_objective(2, -0.02);
        lp.set_objective(3, 6.0);
        lp.add_row(vec![(0, 0.25), (1, -60.0), (2, -0.04), (3, 9.0)], Sense::Le, 0.0);
        lp.add_row(vec![(0, 0.5), (1, -90.0), (2, -0.02), (3, 3.0)], Sense::Le, 0.0);
        lp.add_row(vec![(2, 1.0)], Sense::Le, 1.0);
        match lp.solve(1000) {
            LpOutcome::Optimal { objective, .. } => {
                assert!((objective - (-0.05)).abs() < 1e-6, "obj {objective}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn transportation_like_problem() {
        // 2 sources (supply 5, 5) x 2 sinks (demand 4, 6); costs
        // c11=1 c12=3 c21=2 c22=1. Optimal: x11=4, x22=5, x12=1 -> 4+3+5=12.
        let mut lp = Lp::new(4); // x11 x12 x21 x22
        for (v, c) in [(0, 1.0), (1, 3.0), (2, 2.0), (3, 1.0)] {
            lp.set_objective(v, c);
        }
        lp.add_row(vec![(0, 1.0), (1, 1.0)], Sense::Eq, 5.0);
        lp.add_row(vec![(2, 1.0), (3, 1.0)], Sense::Eq, 5.0);
        lp.add_row(vec![(0, 1.0), (2, 1.0)], Sense::Eq, 4.0);
        lp.add_row(vec![(1, 1.0), (3, 1.0)], Sense::Eq, 6.0);
        assert_opt(lp.solve(200), 12.0, None);
    }

    #[test]
    fn iteration_limit_reported() {
        let mut lp = Lp::new(2);
        lp.set_objective(0, -1.0);
        lp.add_row(vec![(0, 1.0), (1, 1.0)], Sense::Le, 2.0);
        assert_eq!(lp.solve(0), LpOutcome::IterationLimit);
    }

    #[test]
    fn expired_deadline_is_distinguishable() {
        // A hit deadline must not masquerade as Infeasible or as a pivot
        // budget exhaustion — callers need to tell the three apart.
        let mut lp = Lp::new(2);
        lp.set_objective(0, 1.0);
        lp.set_objective(1, 2.0);
        lp.add_row(vec![(0, 1.0), (1, 1.0)], Sense::Ge, 4.0);
        lp.add_row(vec![(0, 1.0)], Sense::Le, 1.0);
        let dead = crate::util::timer::Deadline::after(std::time::Duration::ZERO);
        assert_eq!(lp.solve_with_deadline(10_000, dead), LpOutcome::DeadlineExpired);
        // The pivot budget path still reports IterationLimit.
        assert_eq!(lp.solve(0), LpOutcome::IterationLimit);
    }

    #[test]
    fn solve_and_solve_with_deadline_agree_when_not_expired() {
        // Pin: with an unexpired deadline both entry points return the
        // same LpOutcome for the same instance, across outcome kinds.
        let unbounded = crate::util::timer::Deadline::unbounded;

        // Optimal.
        let mut opt = Lp::new(2);
        opt.set_objective(0, 2.0);
        opt.set_objective(1, 3.0);
        opt.add_row(vec![(0, 1.0), (1, 1.0)], Sense::Ge, 10.0);
        opt.add_row(vec![(0, 1.0)], Sense::Le, 6.0);
        assert_eq!(opt.solve(200), opt.solve_with_deadline(200, unbounded()));

        // Infeasible.
        let mut infeas = Lp::new(1);
        infeas.set_objective(0, 1.0);
        infeas.add_row(vec![(0, 1.0)], Sense::Le, 1.0);
        infeas.add_row(vec![(0, 1.0)], Sense::Ge, 2.0);
        assert_eq!(infeas.solve(100), infeas.solve_with_deadline(100, unbounded()));
        assert_eq!(infeas.solve(100), LpOutcome::Infeasible);

        // Unbounded.
        let mut unb = Lp::new(1);
        unb.set_objective(0, -1.0);
        unb.add_row(vec![(0, 1.0)], Sense::Ge, 0.0);
        assert_eq!(unb.solve(100), unb.solve_with_deadline(100, unbounded()));
        assert_eq!(unb.solve(100), LpOutcome::Unbounded);

        // Iteration limit (pivot budget, not wall clock).
        let mut lim = Lp::new(2);
        lim.set_objective(0, -1.0);
        lim.add_row(vec![(0, 1.0), (1, 1.0)], Sense::Le, 2.0);
        assert_eq!(lim.solve(0), lim.solve_with_deadline(0, unbounded()));
        assert_eq!(lim.solve(0), LpOutcome::IterationLimit);
    }

    #[test]
    fn nan_objective_does_not_panic() {
        // Degenerate goal-weight mixes can leak non-finite costs into the
        // pricing loop; total_cmp keeps entering-column selection total.
        let mut lp = Lp::new(2);
        lp.set_objective(0, f64::NAN);
        lp.set_objective(1, -1.0);
        lp.add_row(vec![(0, 1.0)], Sense::Le, 2.0);
        lp.add_row(vec![(1, 1.0)], Sense::Le, 3.0);
        // Any outcome is acceptable; the property under test is "no panic".
        let _ = lp.solve(100);
    }

    #[test]
    fn moderately_sized_random_lp_solves() {
        // Random feasible LP: min Σx_i with row sums >= targets.
        use crate::util::prng::Pcg64;
        let mut rng = Pcg64::new(99);
        let n = 40;
        let mut lp = Lp::new(n);
        for v in 0..n {
            lp.set_objective(v, rng.uniform(1.0, 2.0));
        }
        for _ in 0..20 {
            let mut coeffs: Vec<(usize, f64)> = Vec::new();
            for v in 0..n {
                if rng.chance(0.3) {
                    coeffs.push((v, rng.uniform(0.5, 1.5)));
                }
            }
            if coeffs.is_empty() {
                continue;
            }
            lp.add_row(coeffs, Sense::Ge, rng.uniform(1.0, 4.0));
        }
        match lp.solve(5000) {
            LpOutcome::Optimal { x, objective } => {
                assert!(objective >= 0.0);
                assert!(x.iter().all(|&v| v >= -1e-9));
            }
            other => panic!("{other:?}"),
        }
    }
}
