//! Ingest-plane contracts (ISSUE 8 acceptance): whatever interleaving
//! concurrent producer threads produce, the admitted-event journal is
//! the single source of truth — replaying it offline reproduces the
//! live run's decision records and fleet checkpoint bit-for-bit, at any
//! local-search worker count. Plus the backpressure policies: Shed
//! drops at the door with an exact per-reason count, Block never drops.

use sptlb::model::{AppId, FleetEvent};
use sptlb::service::{MultiRegionService, Service, ServiceConfig};
use sptlb::util::propcheck::{forall, Check};
use sptlb::util::prng::Pcg64;
use std::time::Duration;

fn config(workers: usize) -> ServiceConfig {
    // Generous solver deadline: termination must come from convergence
    // (`max_stale_restarts`), never wall clock, or replay would not be
    // bit-identical (same discipline as tests/fleet_equivalence.rs).
    ServiceConfig::builder()
        .workload("small")
        .events("drift")
        .variant("no_cnst")
        .timeout(Duration::from_secs(20))
        .batch_budget(Duration::from_millis(1))
        .max_batch(64)
        .queue_capacity(4096)
        .workers(workers)
        .build()
        .unwrap()
}

/// A deterministic per-producer stream: mostly drift, some departures
/// and re-arrivals, all derived from the service's own fleet so most
/// events pass admission (the rest exercise the shed counters).
fn stream(service: &Service, seed: u64, n: usize) -> Vec<FleetEvent> {
    let apps = service.fleet().apps();
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let app = &apps[rng.range(0, apps.len())];
            match rng.range(0, 10) {
                0 => FleetEvent::Departure { app: app.id },
                1 => {
                    let mut newcomer = app.clone();
                    newcomer.name = format!("p{seed}-new");
                    FleetEvent::Arrival { app: newcomer }
                }
                2 => FleetEvent::DemandDrift {
                    // Out past the fleet: shed as unknown_app, never journaled.
                    app: AppId::from_usize(apps.len() + 1000 + rng.range(0, 50)),
                    demand: app.demand,
                },
                _ => FleetEvent::DemandDrift {
                    app: app.id,
                    demand: app.demand * (0.8 + rng.range(0, 41) as f64 / 100.0),
                },
            }
        })
        .collect()
}

/// Drive a live service with `n_producers` concurrent threads and drain
/// it to completion; returns the service plus the number of events the
/// producers successfully queued.
fn run_live(n_producers: usize, seed: u64) -> (Service, u64) {
    let mut service = Service::new(config(1));
    let streams: Vec<Vec<FleetEvent>> = (0..n_producers)
        .map(|i| stream(&service, seed ^ (i as u64 + 1).wrapping_mul(0x9E37), 80))
        .collect();
    let producers: Vec<_> = streams
        .into_iter()
        .map(|events| {
            let h = service.handle();
            std::thread::spawn(move || {
                let mut queued = 0u64;
                for ev in events {
                    if h.submit(ev) {
                        queued += 1;
                    }
                }
                queued
            })
        })
        .collect();
    loop {
        // `is_finished` is loaded *before* the drain: a true value means
        // every push happened-before it, so an empty drain afterwards
        // proves the queue is dry for good.
        let all_done = producers.iter().all(|p| p.is_finished());
        if service.ingest_round().is_none() && all_done {
            break;
        }
    }
    service.stop();
    let queued: u64 = producers.into_iter().map(|p| p.join().expect("producer")).sum();
    (service, queued)
}

#[test]
fn concurrent_producer_interleavings_replay_bit_identically() {
    // The interleaving the threads actually produced is nondeterministic
    // run to run; the property is that the journal captures it exactly:
    // an offline replay — including at other worker counts — reproduces
    // the decision records and the fleet checkpoint bit-for-bit.
    forall(
        2,
        |rng| rng.next_u64() % 1000,
        |&seed| {
            for n_producers in [1usize, 2, 8] {
                let (live, queued) = run_live(n_producers, seed);
                if live.rounds_done() == 0 {
                    return Check::fail(&format!(
                        "producers={n_producers}: no rounds ran"
                    ));
                }
                // Conservation: every queued event was either admitted or
                // shed by admission — none vanished.
                let shed = &live.metrics.ingest.shed;
                let admission_shed = shed.total() - shed.queue_full;
                if live.metrics.ingest.accepted + admission_shed != queued {
                    return Check::fail(&format!(
                        "producers={n_producers}: queued {queued} but accepted {} + shed {}",
                        live.metrics.ingest.accepted, admission_shed
                    ));
                }
                let journal: Vec<Vec<FleetEvent>> = (0..live.rounds_done())
                    .map(|k| live.journal_round(k).to_vec())
                    .collect();
                for workers in [1usize, 2, 8] {
                    let replayed = Service::replay(config(workers), &journal);
                    if replayed.rounds != live.rounds {
                        return Check::fail(&format!(
                            "producers={n_producers} workers={workers}: decision records diverged"
                        ));
                    }
                    if replayed.checkpoint_json().to_string()
                        != live.checkpoint_json().to_string()
                    {
                        return Check::fail(&format!(
                            "producers={n_producers} workers={workers}: checkpoint diverged"
                        ));
                    }
                }
            }
            Check::pass()
        },
    );
}

#[test]
fn shed_policy_drops_at_the_door_and_counts_every_drop() {
    let cfg = ServiceConfig::builder()
        .workload("small")
        .events("drift")
        .variant("no_cnst")
        .timeout(Duration::from_millis(50))
        .batch_budget(Duration::from_millis(1))
        .queue_capacity(8)
        .backpressure("shed")
        .build()
        .unwrap();
    let mut service = Service::new(cfg);
    let events = stream(&service, 7, 50);
    let h = service.handle();
    let queued = events.into_iter().filter(|ev| h.submit(ev.clone())).count() as u64;
    assert_eq!(queued, 8, "a full bounded queue admits exactly its capacity");
    while service.ingest_round().is_some() {}
    assert_eq!(service.metrics.ingest.shed.queue_full, 50 - 8, "every drop is counted");
}

#[test]
fn block_policy_never_drops_under_a_slow_consumer() {
    let cfg = ServiceConfig::builder()
        .workload("small")
        .events("drift")
        .variant("no_cnst")
        .timeout(Duration::from_millis(50))
        .batch_budget(Duration::from_millis(1))
        .queue_capacity(8)
        .backpressure("block")
        .build()
        .unwrap();
    let mut service = Service::new(cfg);
    // Drift-only so everything passes admission and the count is exact.
    let events: Vec<FleetEvent> = stream(&service, 11, 200)
        .into_iter()
        .filter(|e| {
            matches!(e, FleetEvent::DemandDrift { app, .. }
                     if app.idx() < service.fleet().apps().len())
        })
        .collect();
    let n = events.len() as u64;
    let h = service.handle();
    let producer = std::thread::spawn(move || {
        let mut queued = 0u64;
        for ev in events {
            if h.submit(ev) {
                queued += 1;
            }
        }
        queued
    });
    loop {
        let all_done = producer.is_finished();
        if service.ingest_round().is_none() && all_done {
            break;
        }
    }
    service.stop();
    assert_eq!(producer.join().unwrap(), n, "block admits every event");
    assert_eq!(service.metrics.ingest.shed.queue_full, 0, "nothing shed");
    assert_eq!(service.metrics.ingest.accepted, n, "every event reached a solve");
}

// ---- multi-region ingest plane ------------------------------------------

fn multi_config(regions: usize, workers: usize) -> ServiceConfig {
    ServiceConfig::builder()
        .workload("small")
        .events("drift")
        .variant("no_cnst")
        .timeout(Duration::from_secs(20))
        .batch_budget(Duration::from_millis(1))
        .max_batch(64)
        .queue_capacity(4096)
        .regions(regions)
        .workers(workers)
        .build()
        .unwrap()
}

/// Region-local version of [`stream`]: events minted against region
/// `r`'s own fleet, so admission routes and sheds per region.
fn region_stream(service: &MultiRegionService, r: usize, seed: u64, n: usize) -> Vec<FleetEvent> {
    let apps = service.region_fleet(r).apps();
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let app = &apps[rng.range(0, apps.len())];
            match rng.range(0, 10) {
                0 => FleetEvent::Departure { app: app.id },
                1 => {
                    let mut newcomer = app.clone();
                    newcomer.name = format!("r{r}p{seed}-new");
                    FleetEvent::Arrival { app: newcomer }
                }
                2 => FleetEvent::DemandDrift {
                    app: AppId::from_usize(apps.len() + 1000 + rng.range(0, 50)),
                    demand: app.demand,
                },
                _ => FleetEvent::DemandDrift {
                    app: app.id,
                    demand: app.demand * (0.8 + rng.range(0, 41) as f64 / 100.0),
                },
            }
        })
        .collect()
}

/// Drive a live multi-region service with `n_producers` threads per
/// region, each submitting to its own region's queue; drain to
/// completion and return the service plus the queued-event count.
fn run_live_multi(regions: usize, n_producers: usize, seed: u64) -> (MultiRegionService, u64) {
    let mut service = MultiRegionService::new(multi_config(regions, 1));
    let handle = service.handle();
    let mut producers = Vec::new();
    for r in 0..regions {
        for i in 0..n_producers {
            let mix = (r * 8 + i) as u64 + 1;
            let events = region_stream(&service, r, seed ^ mix.wrapping_mul(0x9E37), 60);
            let h = handle.clone();
            producers.push(std::thread::spawn(move || {
                let mut queued = 0u64;
                for ev in events {
                    if h.submit(r, ev) {
                        queued += 1;
                    }
                }
                queued
            }));
        }
    }
    loop {
        let all_done = producers.iter().all(|p| p.is_finished());
        if service.ingest_round().is_none() && all_done {
            break;
        }
    }
    service.stop();
    let queued: u64 = producers.into_iter().map(|p| p.join().expect("producer")).sum();
    (service, queued)
}

#[test]
fn multi_region_journals_replay_bit_identically_at_any_worker_count() {
    // Same property as the single-region check, with a region axis: the
    // region-tagged journal captures whatever interleaving the producer
    // threads actually produced, and replaying it offline reproduces
    // every region's decision records and checkpoint bit-for-bit at any
    // local-search worker count.
    forall(
        2,
        |rng| rng.next_u64() % 1000,
        |&seed| {
            for regions in [1usize, 3] {
                let (live, queued) = run_live_multi(regions, 2, seed);
                if live.rounds_done() == 0 {
                    return Check::fail(&format!("regions={regions}: no rounds ran"));
                }
                // Conservation with a region axis: accepted counts both
                // producer-queued events and the departure/arrival pairs
                // the global layer stages for migrations, so it can only
                // exceed what producers queued minus admission sheds.
                let shed = &live.metrics.ingest.shed;
                let admission_shed = shed.total() - shed.queue_full;
                if live.metrics.ingest.accepted + admission_shed < queued {
                    return Check::fail(&format!(
                        "regions={regions}: queued {queued} but accepted {} + shed {}",
                        live.metrics.ingest.accepted, admission_shed
                    ));
                }
                let journal = live.journal();
                for workers in [1usize, 2, 8] {
                    let cfg = multi_config(regions, workers);
                    let replayed = MultiRegionService::replay(cfg, &journal);
                    for r in 0..regions {
                        if replayed.region_rounds(r) != live.region_rounds(r) {
                            return Check::fail(&format!(
                                "regions={regions} workers={workers}: region {r} records diverged"
                            ));
                        }
                    }
                    if replayed.checkpoint_json().to_string()
                        != live.checkpoint_json().to_string()
                    {
                        return Check::fail(&format!(
                            "regions={regions} workers={workers}: checkpoint diverged"
                        ));
                    }
                }
            }
            Check::pass()
        },
    );
}

#[test]
fn multi_region_snapshot_restores_and_catches_up_from_the_journal() {
    // Kill-at-round-K: a snapshot taken mid-run (reconstructed here by
    // replaying the journal prefix — bit-identical to a live snapshot by
    // the replay contract) plus the full journal restores the service,
    // verifies every region's checkpoint, and replays the tail.
    let (live, _) = run_live_multi(3, 2, 42);
    let rounds = live.rounds_done();
    assert!(rounds >= 2, "need at least two rounds to split ({rounds})");
    let journal = live.journal();
    let k = rounds / 2;
    let at_k = MultiRegionService::replay(multi_config(3, 1), &journal[..k as usize]);
    assert_eq!(at_k.snapshot().rounds_done, k);
    let restored = MultiRegionService::restore(multi_config(3, 2), &at_k.snapshot(), &journal)
        .expect("restore from mid-run snapshot");
    assert_eq!(restored.rounds_done(), rounds, "journal tail replayed on top");
    for r in 0..3 {
        assert_eq!(restored.region_rounds(r), live.region_rounds(r), "region {r} records");
    }
    assert_eq!(
        restored.checkpoint_json().to_string(),
        live.checkpoint_json().to_string(),
        "restored fleets match the live run bit-for-bit"
    );
}

#[test]
fn fabric_spawns_once_and_reuses_workers_across_rounds() {
    let mut service = MultiRegionService::new(multi_config(3, 1));
    assert_eq!(service.fabric_threads_spawned(), 0, "fabric is lazy until the first round");
    let handle = service.handle();
    for round in 0..6usize {
        let r = round % 3;
        let app = service.region_fleet(r).apps()[0].clone();
        let ev = FleetEvent::DemandDrift { app: app.id, demand: app.demand * 1.1 };
        assert!(handle.submit(r, ev));
        while service.ingest_round().is_none() {}
        assert_eq!(service.fabric_threads_spawned(), 3, "no thread spawns after warm-up");
    }
    assert_eq!(service.rounds_done(), 6);
    service.stop();
}
