"""Pallas kernel for batched candidate-assignment scoring (L1).

The hot-spot of SPTLB's LocalSearch is scoring thousands of candidate
assignments per round.  This kernel computes the scoring model documented in
``ref.py`` for a block of candidates at a time.

TPU-shaped design (see DESIGN.md §Hardware-Adaptation):
  * The grid iterates over blocks of the candidate (batch) axis; each grid
    step streams one ``(bB, A, T)`` assignment block HBM→VMEM.
  * The small side inputs (``res`` A×3, ``cap``/``ideal`` T×3, ``init`` A×T,
    ``crit`` A, ``weights`` 6) fit in VMEM and are mapped whole into every
    grid step (index_map → block 0).
  * The contraction ``einsum('bat,ar->btr')`` lowers to a dot_general, which
    the MXU executes; the penalty/reduction epilogue is fused into the same
    kernel so the assignment tensor is read exactly once.
  * f32 accumulation throughout — bf16 would corrupt the small utilization
    deltas the balance goals compare.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the rust
runtime's CPU client runs directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref

# Default candidate-block size.  (bB, A, T) f32 for the default problem
# (A=64, T=5) is 64*64*5*4 B = 80 KiB — comfortably inside a 16 MiB VMEM
# budget together with the epilogue temporaries.
DEFAULT_BLOCK_B = 64


def _score_block_kernel(
    assign_ref,
    res_ref,
    cap_ref,
    ideal_ref,
    init_ref,
    crit_ref,
    w_ref,
    scores_ref,
    loads_ref,
):
    """One grid step: score a (bB, A, T) block of candidates."""
    assign = assign_ref[...]  # (bB, A, T)
    res = res_ref[...]  # (A, R)
    cap = cap_ref[...]  # (T, R)
    ideal = ideal_ref[...]  # (T, R)
    init = init_ref[...]  # (A, T)
    crit = crit_ref[...]  # (A,)
    w = w_ref[...]  # (NUM_WEIGHTS,)

    # MXU contraction: (bB, A, T) x (A, R) -> (bB, T, R).
    loads = jnp.einsum(
        "bat,ar->btr", assign, res, preferred_element_type=jnp.float32
    )
    util = loads / cap[None, :, :]

    cap_vio = jnp.sum(jnp.square(jnp.maximum(util - 1.0, 0.0)), axis=(1, 2))
    over_ideal = jnp.sum(
        jnp.square(jnp.maximum(util - ideal[None, :, :], 0.0)), axis=(1, 2)
    )

    mean_util = jnp.mean(util, axis=1, keepdims=True)
    dev_sq = jnp.square(util - mean_util)
    res_balance = jnp.sum(
        dev_sq[:, :, _ref.R_CPU] + dev_sq[:, :, _ref.R_MEM], axis=1
    )
    task_balance = jnp.sum(dev_sq[:, :, _ref.R_TASK], axis=1)

    stay = jnp.sum(assign * init[None, :, :], axis=2)
    moved = 1.0 - stay
    task_total = jnp.maximum(jnp.sum(res[:, _ref.R_TASK]), 1.0)
    crit_total = jnp.maximum(jnp.sum(crit), 1e-12)
    move_cost = jnp.sum(moved * res[None, :, _ref.R_TASK], axis=1) / task_total
    crit_cost = jnp.sum(moved * crit[None, :], axis=1) / crit_total

    scores_ref[...] = (
        w[_ref.W_CAPACITY] * cap_vio
        + w[_ref.W_UTIL_LIMIT] * over_ideal
        + w[_ref.W_RES_BALANCE] * res_balance
        + w[_ref.W_TASK_BALANCE] * task_balance
        + w[_ref.W_MOVE_COST] * move_cost
        + w[_ref.W_CRITICALITY] * crit_cost
    )
    loads_ref[...] = loads


def best_block_b(b: int, limit: int = DEFAULT_BLOCK_B) -> int:
    """Largest divisor of ``b`` not exceeding ``limit``."""
    for cand in range(min(b, limit), 0, -1):
        if b % cand == 0:
            return cand
    return 1


def score_candidates_pallas(
    assign, res, cap, ideal, init, crit, weights, *, block_b=None
):
    """Pallas-kernel scorer; drop-in for ``ref.score_candidates_ref``.

    ``B`` must be a multiple of ``block_b``; when ``block_b`` is None the
    largest divisor of B not exceeding ``DEFAULT_BLOCK_B`` is chosen (the
    AOT entry point fixes all shapes at lowering time so the rust side
    never pads mid-flight).
    """
    if block_b is None:
        block_b = best_block_b(assign.shape[0])
    return _score_candidates_jit(
        assign, res, cap, ideal, init, crit, weights, block_b=block_b
    )


@functools.partial(jax.jit, static_argnames=("block_b",))
def _score_candidates_jit(
    assign, res, cap, ideal, init, crit, weights, *, block_b
):
    b, a, t = assign.shape
    r = res.shape[1]
    if b % block_b != 0:
        raise ValueError(f"batch {b} not a multiple of block_b {block_b}")
    grid = (b // block_b,)

    whole = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    return pl.pallas_call(
        _score_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, a, t), lambda i: (i, 0, 0)),
            whole((a, r)),
            whole((t, r)),
            whole((t, r)),
            whole((a, t)),
            whole((a,)),
            whole((_ref.NUM_WEIGHTS,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, t, r), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, t, r), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(
        assign.astype(jnp.float32),
        res.astype(jnp.float32),
        cap.astype(jnp.float32),
        ideal.astype(jnp.float32),
        init.astype(jnp.float32),
        crit.astype(jnp.float32),
        weights.astype(jnp.float32),
    )
