//! The co-operation protocol (§3.4, Fig. 2): SPTLB proposes an app→tier
//! mapping; the region scheduler vets each move (near-data-source test);
//! surviving moves are vetted by the host scheduler (packing test). Every
//! rejected move comes back to SPTLB as an *avoid constraint* (the same
//! mechanism as C4's SLO avoids) and SPTLB re-solves. "These iterations
//! continue until SPTLB times out or the number of iterations limit is
//! reached."
//!
//! The round structure itself — budget split, accept test, rejection
//! feedback, telemetry — lives in the shared [`crate::coop`] kernel;
//! this module only binds the SPTLB layer's domain into it: a private
//! `ProtocolSession` implements [`CoopLayer`] with solutions as
//! proposals, moves as items, and the region/host schedulers as vetters.

use crate::coop::{negotiate, CoopLayer, DecisionKey, RejectCounts, RoundTelemetry, Verdict};
use crate::hierarchy::host::HostScheduler;
use crate::hierarchy::region::{RegionScheduler, RegionVerdict};
use crate::model::{App, Assignment, Move, ResourceVec, Tier};
use crate::obs;
use crate::rebalancer::local_search::{LocalSearch, LocalSearchConfig, ParallelConfig};
use crate::rebalancer::optimal::OptimalSearch;
use crate::rebalancer::problem::Problem;
use crate::rebalancer::solution::{Solution, SolverKind};
use crate::util::timer::Deadline;
use std::time::Duration;

/// Per-round record for tracing / Fig. 2 demos.
#[derive(Debug, Clone)]
pub struct RoundTrace {
    pub round: u32,
    pub proposed_moves: usize,
    pub region_rejects: usize,
    pub host_rejects: usize,
    /// Rejections by reason — the kernel's uniform telemetry.
    pub rejects: RejectCounts,
    pub avoid_edges_added: usize,
    pub score: f64,
}

impl RoundTrace {
    /// Project the kernel's uniform telemetry into this layer's trace:
    /// the region scheduler owns proximity + transition rejections, the
    /// host scheduler owns packing.
    fn from_telemetry(t: &RoundTelemetry) -> Self {
        Self {
            round: t.round,
            proposed_moves: t.proposed,
            region_rejects: t.rejects.proximity + t.rejects.transition,
            host_rejects: t.rejects.packing,
            rejects: t.rejects,
            avoid_edges_added: t.avoids_added,
            score: t.score,
        }
    }
}

/// Protocol outcome.
#[derive(Debug, Clone)]
pub struct CoopOutcome {
    /// The accepted (or best-effort, on limit/timeout) solution.
    pub solution: Solution,
    pub rounds: Vec<RoundTrace>,
    /// True if every proposed move was accepted by both schedulers.
    pub fully_accepted: bool,
    pub elapsed: Duration,
}

impl CoopOutcome {
    /// Total rejections across all rounds, by reason.
    pub fn rejects(&self) -> RejectCounts {
        let mut total = RejectCounts::default();
        for r in &self.rounds {
            total.add(&r.rejects);
        }
        total
    }
}

/// Protocol configuration.
#[derive(Debug, Clone)]
pub struct CoopConfig {
    pub max_rounds: u32,
    pub solver: SolverKind,
    /// Sharded-scan parallelism forwarded to each round's LocalSearch.
    pub parallel: ParallelConfig,
    pub seed: u64,
}

impl Default for CoopConfig {
    fn default() -> Self {
        Self {
            max_rounds: 8,
            solver: SolverKind::LocalSearch,
            parallel: ParallelConfig::default(),
            seed: 0xC0,
        }
    }
}

/// Runs SPTLB ↔ region ↔ host co-operation rounds.
pub struct CoopProtocol {
    pub region: RegionScheduler,
    pub host: HostScheduler,
    pub config: CoopConfig,
}

/// The SPTLB layer's binding into the shared negotiation kernel: one
/// `negotiate()` run's mutable state (warm start, best-so-far fallback)
/// plus borrows of the domain the vetters need.
struct ProtocolSession<'a> {
    proto: &'a CoopProtocol,
    problem: &'a mut Problem,
    apps: &'a [App],
    tiers: &'a [Tier],
    warm_loads: Option<&'a [ResourceVec]>,
    /// Previous round's proposal minus its rejected moves: avoid edges
    /// only *remove* options, so it is a strong, feasible warm start.
    warm_start: Option<Assignment>,
    /// Best acceptable solution seen so far (the fallback on limit or
    /// timeout).
    best: Option<Solution>,
}

impl CoopLayer for ProtocolSession<'_> {
    type Proposal = Solution;
    type Item = Move;

    /// SPTLB solve, warm-started from the previous (cleaned) proposal
    /// when one exists; any round that solves from `problem.initial` (in
    /// practice the first) may reuse the caller's cached per-tier
    /// aggregates instead of re-accumulating them.
    fn propose(&mut self, round: u32, round_deadline: Deadline) -> Solution {
        let cfg = &self.proto.config;
        let local = |seed: u64| {
            LocalSearch::new(LocalSearchConfig {
                seed,
                parallel: cfg.parallel,
                ..LocalSearchConfig::default()
            })
        };
        match (cfg.solver, &self.warm_start) {
            (SolverKind::LocalSearch, Some(start)) => local(cfg.seed + round as u64)
                .solve_from(self.problem, round_deadline, start),
            (SolverKind::LocalSearch, None) => match self.warm_loads {
                // Solving from the incumbent: the caller's cached
                // aggregates apply verbatim.
                Some(loads) => {
                    local(cfg.seed + round as u64).solve_warm(self.problem, round_deadline, loads)
                }
                None => local(cfg.seed + round as u64).solve(self.problem, round_deadline),
            },
            (SolverKind::OptimalSearch, _) => OptimalSearch::with_seed(cfg.seed + round as u64)
                .solve(self.problem, round_deadline),
        }
    }

    fn items(&self, proposal: &Solution) -> Vec<Move> {
        proposal.moves(self.problem)
    }

    /// Two-stage vetting, exactly as Fig. 2 draws it: the region
    /// scheduler sees every move, the host scheduler only the survivors.
    fn vet(&mut self, proposal: &Solution, items: &[Move]) -> Vec<Verdict> {
        let region_verdicts = self.proto.region.vet(items, self.apps, self.tiers);
        let surviving: Vec<Move> = region_verdicts
            .iter()
            .filter(|(_, v)| matches!(v, RegionVerdict::Accept))
            .map(|(m, _)| *m)
            .collect();
        let host_verdicts = self.proto.host.vet(&surviving, &proposal.assignment, self.apps);
        let mut host_iter = host_verdicts.iter();
        region_verdicts
            .iter()
            .map(|(m, rv)| match rv {
                RegionVerdict::Accept => {
                    let (hm, hv) = host_iter.next().expect("one host verdict per survivor");
                    debug_assert_eq!(hm, m, "host verdicts align with survivors");
                    hv.to_coop()
                }
                _ => rv.to_coop(),
            })
            .collect()
    }

    /// Feed a rejection back into the problem. Transition rejections ban
    /// the tier→tier transition globally (§4.2.2: manual_cnst "deters
    /// transitions ... detected as high latency"); data-proximity and
    /// host rejections only avoid the specific (app, tier) placement.
    fn feed_back(&mut self, m: &Move, verdict: &Verdict) -> bool {
        match verdict {
            Verdict::Accept => false,
            Verdict::RejectTransition(_) => {
                if !self.problem.forbidden_transitions.contains(&(m.from, m.to)) {
                    self.problem.forbid_transition(m.from, m.to);
                    true
                } else {
                    false
                }
            }
            Verdict::Reject(_) => self.problem.add_avoid(m.app, m.to),
        }
    }

    fn score(&self, proposal: &Solution) -> f64 {
        proposal.score
    }

    /// A cleaned copy of the proposal (rejected moves reverted) is both
    /// the next round's warm start and the acceptable fallback solution.
    fn absorb(&mut self, solution: Solution, vetted: &[(Move, Verdict)], accepted: bool) {
        let mut cleaned = solution.assignment.clone();
        for (m, v) in vetted {
            if !v.is_accept() {
                cleaned.set(m.app, m.from);
            }
        }
        let candidate = if accepted {
            solution
        } else {
            Solution::of_assignment(self.problem, cleaned.clone(), self.proto.config.solver)
        };
        if self.best.as_ref().map_or(true, |b| candidate.score < b.score) {
            self.best = Some(candidate);
        }
        if !accepted {
            self.warm_start = Some(cleaned);
        }
    }

    /// Tier-level provenance: `from`/`to` are tier ids.
    fn describe(&self, m: &Move) -> Option<DecisionKey> {
        Some(DecisionKey {
            app: m.app.0,
            from: m.from.0 as i64,
            to: m.to.0 as i64,
            origin: obs::Origin::Protocol,
        })
    }
}

impl CoopProtocol {
    pub fn new(region: RegionScheduler, host: HostScheduler, config: CoopConfig) -> Self {
        Self { region, host, config }
    }

    /// Run the protocol. `problem` accumulates avoid constraints across
    /// rounds (the caller keeps the mutated problem for inspection).
    /// `apps`/`tiers` are the domain views the lower-level schedulers
    /// need (regions, preferred regions, host fleets).
    pub fn run(
        &self,
        problem: &mut Problem,
        apps: &[App],
        tiers: &[crate::model::Tier],
        deadline: Deadline,
    ) -> CoopOutcome {
        self.run_warm(problem, apps, tiers, deadline, None)
    }

    /// [`CoopProtocol::run`] with optionally warm-started incumbent
    /// loads: any round that solves from `problem.initial` (in practice
    /// the first) reuses the caller's cached per-tier aggregates instead
    /// of re-accumulating them. Loads must be bit-identical to a fresh
    /// accumulation, so the outcome equals the cold path exactly.
    pub fn run_warm(
        &self,
        problem: &mut Problem,
        apps: &[App],
        tiers: &[crate::model::Tier],
        deadline: Deadline,
        warm_loads: Option<&[crate::model::ResourceVec]>,
    ) -> CoopOutcome {
        let mut session = ProtocolSession {
            proto: self,
            problem: &mut *problem,
            apps,
            tiers,
            warm_loads,
            warm_start: None,
            best: None,
        };
        let outcome = negotiate(&mut session, self.config.max_rounds, deadline);
        let ProtocolSession { best, .. } = session;
        let solution = best.unwrap_or_else(|| {
            Solution::of_assignment(problem, problem.initial.clone(), self.config.solver)
        });
        CoopOutcome {
            solution,
            rounds: outcome.rounds.iter().map(RoundTrace::from_telemetry).collect(),
            fully_accepted: outcome.fully_accepted,
            elapsed: deadline.elapsed(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rebalancer::constraints::{validate, Violation};
    use crate::rebalancer::problem::GoalWeights;
    use crate::rebalancer::scoring::score_assignment;
    use crate::workload::{generate, WorkloadSpec};

    fn setup(
        proximity_ms: f64,
    ) -> (Problem, Vec<App>, Vec<crate::model::Tier>, CoopProtocol) {
        let bed = generate(&WorkloadSpec::paper());
        let problem = Problem::build(
            &bed.apps,
            &bed.tiers,
            bed.initial.clone(),
            0.10,
            GoalWeights::default(),
        )
        .unwrap();
        let region = RegionScheduler::new(bed.latency.clone(), proximity_ms);
        let host = HostScheduler::uniform(&bed.tiers, 16);
        let proto = CoopProtocol::new(region, host, CoopConfig::default());
        (problem, bed.apps, bed.tiers, proto)
    }

    #[test]
    fn generous_budget_accepts_quickly() {
        let (mut p, apps, tiers, proto) = setup(1e6);
        let out = proto.run(&mut p, &apps, &tiers, Deadline::after_ms(400));
        assert!(out.fully_accepted);
        assert_eq!(out.rounds.last().unwrap().region_rejects, 0);
    }

    #[test]
    fn strict_budget_adds_avoids_and_converges() {
        let (mut p, apps, tiers, proto) = setup(8.0);
        let allowed_before: usize = p.apps.iter().map(|a| a.allowed.len()).sum();
        let out = proto.run(&mut p, &apps, &tiers, Deadline::after_ms(600));
        let allowed_after: usize = p.apps.iter().map(|a| a.allowed.len()).sum();
        // Either accepted outright (no rejects ever) or avoid edges were
        // added along the way.
        if out.rounds.iter().any(|r| r.region_rejects + r.host_rejects > 0) {
            assert!(allowed_after < allowed_before, "avoid edges must shrink sets");
        }
        // The returned solution's own moves are all acceptable: re-vet.
        let moves = out.solution.moves(&p);
        let verdicts = proto.region.vet(&moves, &apps, &tiers);
        assert!(verdicts
            .iter()
            .all(|(_, v)| matches!(v, RegionVerdict::Accept)));
    }

    #[test]
    fn outcome_improves_over_incumbent() {
        let (mut p, apps, tiers, proto) = setup(25.0);
        let (initial_score, _) = score_assignment(&p, &p.initial);
        let out = proto.run(&mut p, &apps, &tiers, Deadline::after_ms(600));
        assert!(out.solution.score <= initial_score);
    }

    #[test]
    fn solution_respects_constraints() {
        let (mut p, apps, tiers, proto) = setup(15.0);
        let out = proto.run(&mut p, &apps, &tiers, Deadline::after_ms(400));
        let vs = validate(&p, &out.solution.assignment);
        assert!(
            vs.iter().all(|v| matches!(v, Violation::CapacityExceeded { .. })),
            "{vs:?}"
        );
    }

    #[test]
    fn round_limit_respected() {
        let (mut p, apps, tiers, mut proto) = setup(0.0); // reject everything
        proto.config.max_rounds = 3;
        let out = proto.run(&mut p, &apps, &tiers, Deadline::after_ms(500));
        assert!(out.rounds.len() <= 3);
        // With an impossible proximity budget the protocol cannot fully
        // accept any non-empty move set; it must fall back gracefully.
        let moves = out.solution.moves(&p);
        let verdicts = proto.region.vet(&moves, &apps, &tiers);
        assert!(verdicts
            .iter()
            .all(|(_, v)| matches!(v, RegionVerdict::Accept)));
    }

    #[test]
    fn trace_reason_counts_match_the_legacy_split() {
        // The kernel tallies rejections by reason; the legacy
        // region/host split must be a pure projection of it.
        let (mut p, apps, tiers, proto) = setup(-1.0);
        let out = proto.run(&mut p, &apps, &tiers, Deadline::after_ms(400));
        for r in &out.rounds {
            assert_eq!(r.region_rejects, r.rejects.proximity + r.rejects.transition);
            assert_eq!(r.host_rejects, r.rejects.packing);
            assert_eq!(r.rejects.capacity + r.rejects.routability, 0);
        }
        let total = out.rejects();
        assert_eq!(
            total.total(),
            out.rounds.iter().map(|r| r.region_rejects + r.host_rejects).sum::<usize>()
        );
    }
}
